//! Kernel-dispatch speedup microbench: runs the same hot paths twice in
//! one process — once on a pool pinned to the portable scalar kernels,
//! once on the runtime-selected backend (`Kernels::select()`, which
//! honors the `PLNMF_KERNELS` override) — and reports the per-step
//! speedup ratio.
//!
//! Steps cover the refactored layers end to end: the tiled-HALS engine
//! (fig6's hot path), naive FastHALS (fig7's baseline), the MU engine's
//! dense denominators, and a warm serving projection round. On a host
//! without AVX2 (or with `PLNMF_KERNELS=scalar`) both columns run the
//! same code and the ratio prints ≈1.0 — the CSV still documents which
//! backends were measured.
//!
//! Run via `plnmf bench kernels`; writes `kernels_speedup.csv`.

use std::path::Path;
use std::sync::Arc;

use crate::data::{load_dataset, DataMatrix, Dataset};
use crate::kernels::Kernels;
use crate::linalg::Mat;
use crate::nmf::fasthals::FastHalsEngine;
use crate::nmf::mu::MuEngine;
use crate::nmf::plnmf::PlNmfEngine;
use crate::nmf::{cost_model, Factors, NmfEngine};
use crate::parallel::{pool::default_threads, ThreadPool};
use crate::serve::{OwnedQueries, Projector, ProjectorOpts};
use crate::Result;

use super::report::write_csv;
use super::Scale;

/// Docs in the serving-projection step (columns of A, rows of Aᵀ).
const SERVE_DOCS: usize = 256;

/// One backend's timings, step name → seconds per iteration/round.
pub fn time_steps(
    kern: &'static Kernels,
    ds: &Arc<Dataset>,
    k: usize,
    iters: usize,
    threads: usize,
    cache_bytes: usize,
) -> Result<Vec<(&'static str, f64)>> {
    let pool = Arc::new(ThreadPool::with_kernels(threads, kern));
    let mut out = Vec::new();

    let t_star = cost_model::select_tile(k, cache_bytes);
    let mut plnmf = PlNmfEngine::new(ds.clone(), pool.clone(), k, 42, t_star, cache_bytes);
    out.push(("plnmf_step", time_engine(&mut plnmf, iters)?));

    let mut fasthals = FastHalsEngine::new(ds.clone(), pool.clone(), k, 42);
    out.push(("fasthals_step", time_engine(&mut fasthals, iters)?));

    let mut mu = MuEngine::new(ds.clone(), pool.clone(), k, 42);
    out.push(("mu_step", time_engine(&mut mu, iters)?));

    // Warm serving round: one untimed projection touches every buffer,
    // then the timed rounds measure the steady-state solve path.
    let factors = Factors::random(ds.v(), ds.d(), k, 42);
    let n_docs = ds.d().min(SERVE_DOCS);
    let owned = match &ds.at {
        DataMatrix::Sparse(c) => OwnedQueries::Sparse(c.slice_rows(0, n_docs)),
        DataMatrix::Dense(m) => {
            OwnedQueries::Dense(Mat::from_fn(n_docs, m.cols(), |i, j| m.at(i, j)))
        }
    };
    let opts = ProjectorOpts { sweeps: 8, micro_batch: 32, ..Default::default() };
    let projector = Projector::new(factors.w, pool, opts)?;
    projector.project(owned.as_queries())?;
    let timer = std::time::Instant::now();
    for _ in 0..iters {
        projector.project(owned.as_queries())?;
    }
    out.push(("serving_project_warm", timer.elapsed().as_secs_f64() / iters as f64));

    Ok(out)
}

fn time_engine(engine: &mut dyn NmfEngine, iters: usize) -> Result<f64> {
    engine.step()?; // untimed warmup: touches all buffers
    let timer = std::time::Instant::now();
    for _ in 0..iters {
        engine.step()?;
    }
    Ok(timer.elapsed().as_secs_f64() / iters as f64)
}

pub fn run(scale: Scale, out_dir: &Path) -> Result<()> {
    let (dataset, iters) = match scale {
        Scale::Small => ("20news-small", 10),
        Scale::Paper => ("20news", 6),
    };
    let k = scale.k_single();
    let cache = 35 * 1024 * 1024;
    let threads = default_threads();
    let ds = Arc::new(load_dataset(dataset, 42)?);

    let base = Kernels::scalar();
    let fast = Kernels::select();
    println!(
        "kernel speedup on {dataset} (V={}, D={}, K={k}, {threads} threads): \
         {} vs {}\n",
        ds.v(),
        ds.d(),
        base.name(),
        fast.name()
    );

    let base_times = time_steps(base, &ds, k, iters, threads, cache)?;
    let fast_times = time_steps(fast, &ds, k, iters, threads, cache)?;

    let mut rows = Vec::new();
    println!("{:<22} {:>12} {:>12} {:>8}", "step", base.name(), fast.name(), "ratio");
    for ((name, b), (name2, f)) in base_times.iter().zip(&fast_times) {
        debug_assert_eq!(name, name2);
        let ratio = b / f.max(1e-12);
        println!("{name:<22} {b:>11.4}s {f:>11.4}s {ratio:>7.2}×");
        rows.push(format!(
            "{name},{},{},{b:.6},{f:.6},{ratio:.3}",
            base.name(),
            fast.name()
        ));
    }
    let csv = out_dir.join("kernels_speedup.csv");
    write_csv(
        &csv,
        "step,baseline_backend,selected_backend,baseline_secs,selected_secs,speedup",
        &rows,
    )?;
    println!("\nCSV: {}", csv.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_backends_time_every_step() {
        let ds = Arc::new(load_dataset("tiny", 42).unwrap());
        for kern in [Kernels::scalar(), Kernels::detected()] {
            let times = time_steps(kern, &ds, 4, 1, 2, 1 << 20).unwrap();
            let names: Vec<&str> = times.iter().map(|(n, _)| *n).collect();
            assert_eq!(
                names,
                ["plnmf_step", "fasthals_step", "mu_step", "serving_project_warm"]
            );
            assert!(times.iter().all(|(_, s)| *s > 0.0 && s.is_finite()));
        }
    }
}

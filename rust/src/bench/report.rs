//! Bench output plumbing: the `results/` directory and CSV writers.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::cli::Args;
use crate::Result;

/// Resolve the output directory (`--out-dir`, default `results/`).
pub fn results_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt("out-dir").unwrap_or("results"))
}

/// Write CSV rows with a header; creates parent dirs.
pub fn write_csv(path: &Path, header: &str, rows: &[String]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).ok();
        }
    }
    let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "{header}")?;
    for r in rows {
        writeln!(w, "{r}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_rows() {
        let p = std::env::temp_dir().join(format!("plnmf-rep-{}.csv", std::process::id()));
        write_csv(&p, "a,b", &["1,2".into(), "3,4".into()]).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "a,b\n1,2\n3,4\n");
        std::fs::remove_file(p).ok();
    }
}

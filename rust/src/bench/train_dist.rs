//! Distributed-training scaling: wall-clock of a fixed FAST-HALS run
//! driven by `plnmf train-dist` over 1 / 2 / 4 training workers, plus a
//! 2×2 grid row at the same worker count.
//!
//! The coordinator ships nnz-balanced blocks of A once, then each epoch
//! exchanges factor panels and all-reduces the workers' k×k Grams and
//! partial products over the PLNB v2 binary wire — so the `dist_w1` row
//! is (single-process math + one wire hop), the `dist_w2`/`dist_w4`
//! deltas are what shard parallelism buys after communication costs,
//! and the `dist_g2x2` row shows the 2D grid's per-epoch coordinator
//! traffic sitting below the 1D plan at equal worker count (panels
//! instead of full-W broadcast).
//!
//! Workers here are in-process `Server::bind` daemons addressed through
//! attach mode — the exact byte protocol of spawned `plnmf serve
//! --train_worker` processes, without requiring the binary on disk, so
//! the bench stays self-contained in the library (`plnmf bench
//! train-dist` / `cargo bench`).

use std::net::SocketAddr;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::bench::harness::{measure, row, BenchOpts};
use crate::bench::Scale;
use crate::config::RunConfig;
use crate::dist::{train_dist_with_stats, DistOpts};
use crate::serve::{Client, ModelRegistry, RegistryOpts, Server};
use crate::util::json::Json;
use crate::Result;

use super::report::write_csv;

/// Worker counts of the 1D scaling rows (`dist_w{N}` in the CSV).
pub const WORKER_COUNTS: [usize; 3] = [1, 2, 4];

/// The 2D topology row: a 2×2 grid over four workers (`dist_g2x2`).
pub const GRID: (usize, usize) = (2, 2);

pub fn run(scale: Scale, out: &Path) -> Result<()> {
    run_with(scale, out, BenchOpts::default())
}

/// An empty-registry daemon thread — every daemon hosts training jobs,
/// so no models are needed (the `--train_worker` process shape).
fn spawn_inproc_worker() -> Result<SocketAddr> {
    let registry = Arc::new(ModelRegistry::new(RegistryOpts::default()));
    let server = Server::bind(registry, "127.0.0.1", 0)?;
    let addr = server.local_addr();
    std::thread::spawn(move || {
        let _ = server.run();
    });
    Ok(addr)
}

fn shutdown_worker(addr: SocketAddr) {
    if let Ok(mut c) = Client::connect(addr) {
        let _ = c.set_read_timeout(Some(Duration::from_secs(2)));
        let _ = c.request(&Json::obj(vec![("op", Json::str("shutdown"))]));
    }
}

/// [`run`] with explicit measurement options (tests pass fast settings
/// directly instead of tunneling them through env vars).
pub fn run_with(scale: Scale, out: &Path, bench_opts: BenchOpts) -> Result<()> {
    // The rows measure distribution overhead and shard parallelism on a
    // fixed iteration budget, not convergence — small corpora suffice.
    let (dataset, k, iters) = match scale {
        Scale::Small => ("tiny-sparse", 8, 6),
        Scale::Paper => ("20news-small", 32, 15),
    };
    let mut cfg = RunConfig::default();
    cfg.dataset = dataset.to_string();
    cfg.engine = crate::config::EngineKind::FastHals;
    cfg.k = k;
    cfg.max_iters = iters;
    cfg.record_every = iters;
    cfg.threads = 2;
    cfg.seed = 42;

    println!("distributed training on {dataset} (k={k}, {iters} epochs, sync_every=2):\n");
    let mut rows = Vec::new();
    // The 1D scaling ladder, then the 2×2 grid at the top worker count.
    let topologies: Vec<(String, usize, Option<(usize, usize)>)> = WORKER_COUNTS
        .iter()
        .map(|&n| (format!("dist_w{n}"), n, None))
        .chain(std::iter::once((
            format!("dist_g{}x{}", GRID.0, GRID.1),
            GRID.0 * GRID.1,
            Some(GRID),
        )))
        .collect();
    for (name, n, grid) in topologies {
        let workers: Vec<SocketAddr> =
            (0..n).map(|_| spawn_inproc_worker()).collect::<Result<_>>()?;
        let mut final_rel_error = f64::NAN;
        let mut bytes_per_epoch = 0u64;
        let s = measure(bench_opts, || {
            let opts = DistOpts {
                attach: workers.clone(),
                sync_every: 2,
                grid,
                ..DistOpts::default()
            };
            let (report, stats) =
                train_dist_with_stats(&cfg, &opts).expect("train-dist bench run failed");
            final_rel_error = report.final_rel_error;
            bytes_per_epoch = stats.bytes_per_epoch();
        });
        for &addr in &workers {
            shutdown_worker(addr);
        }
        println!(
            "{}  [rel_error {final_rel_error:.4}, {bytes_per_epoch} coord bytes/epoch]",
            row(&name, &s)
        );
        rows.push(format!(
            "{dataset},{k},{iters},{name},{n},{:.6},{:.6},{:.6},{final_rel_error:.6},{bytes_per_epoch}",
            s.median, s.min, s.max
        ));
    }
    let csv = out.join("train_dist.csv");
    write_csv(
        &csv,
        "dataset,k,iters,mode,workers,secs_median,secs_min,secs_max,final_rel_error,coord_bytes_per_epoch",
        &rows,
    )?;
    println!("\nCSV: {}", csv.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_scaling_rows_for_every_worker_count_and_the_grid() {
        let dir = std::env::temp_dir().join(format!("plnmf-distbench-{}", std::process::id()));
        run_with(Scale::Small, &dir, BenchOpts { warmup: 0, reps: 1 }).unwrap();
        let body = std::fs::read_to_string(dir.join("train_dist.csv")).unwrap();
        assert!(body.starts_with("dataset,k,iters,mode,workers"), "{body}");
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 1 + WORKER_COUNTS.len() + 1, "{body}");
        let field = |line: &str, i: usize| line.split(',').nth(i).unwrap().to_string();
        for (i, n) in WORKER_COUNTS.iter().enumerate() {
            let line = lines[1 + i];
            assert!(line.contains(&format!(",dist_w{n},{n},")), "row w={n} missing: {body}");
            let secs: f64 = field(line, 5).parse().unwrap();
            assert!(secs > 0.0, "unmeasured row: {line}");
            let err: f64 = field(line, 8).parse().unwrap();
            assert!(err.is_finite() && err > 0.0 && err < 1.0, "bad rel_error: {line}");
            let bytes: u64 = field(line, 9).parse().unwrap();
            assert!(bytes > 0, "untracked traffic: {line}");
        }
        let grid_line = lines[1 + WORKER_COUNTS.len()];
        assert!(grid_line.contains(",dist_g2x2,4,"), "grid row missing: {body}");
        let grid_err: f64 = field(grid_line, 8).parse().unwrap();
        assert!(
            grid_err.is_finite() && grid_err > 0.0 && grid_err < 1.0,
            "bad rel_error: {grid_line}"
        );
        // The whole point of the 2D grid: per-epoch coordinator traffic
        // below the 1D plan at the same worker count.
        let w4_line = lines[1 + WORKER_COUNTS.iter().position(|&n| n == 4).unwrap()];
        let w4_bytes: u64 = field(w4_line, 9).parse().unwrap();
        let grid_bytes: u64 = field(grid_line, 9).parse().unwrap();
        assert!(
            grid_bytes < w4_bytes,
            "grid traffic {grid_bytes} not below 1D {w4_bytes}: {body}"
        );
        std::fs::remove_dir_all(dir).ok();
    }
}

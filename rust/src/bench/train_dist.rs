//! Distributed-training scaling: wall-clock of a fixed FAST-HALS run
//! driven by `plnmf train-dist` over 1 / 2 / 4 training workers.
//!
//! The coordinator ships nnz-balanced row shards of Aᵀ once, then each
//! epoch broadcasts W and all-reduces the workers' k×k Grams and V×k
//! partial products over the PLNB v2 binary wire — so the `dist_w1` row
//! is (single-process math + one wire hop) and the `dist_w2`/`dist_w4`
//! deltas are what shard parallelism buys after communication costs.
//!
//! Workers here are in-process `Server::bind` daemons addressed through
//! attach mode — the exact byte protocol of spawned `plnmf serve
//! --train_worker` processes, without requiring the binary on disk, so
//! the bench stays self-contained in the library (`plnmf bench
//! train-dist` / `cargo bench`).

use std::net::SocketAddr;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::bench::harness::{measure, row, BenchOpts};
use crate::bench::Scale;
use crate::config::RunConfig;
use crate::dist::{train_dist, DistOpts};
use crate::serve::{Client, ModelRegistry, RegistryOpts, Server};
use crate::util::json::Json;
use crate::Result;

use super::report::write_csv;

/// Worker counts of the scaling rows (`dist_w{N}` in the CSV).
pub const WORKER_COUNTS: [usize; 3] = [1, 2, 4];

pub fn run(scale: Scale, out: &Path) -> Result<()> {
    run_with(scale, out, BenchOpts::default())
}

/// An empty-registry daemon thread — every daemon hosts training jobs,
/// so no models are needed (the `--train_worker` process shape).
fn spawn_inproc_worker() -> Result<SocketAddr> {
    let registry = Arc::new(ModelRegistry::new(RegistryOpts::default()));
    let server = Server::bind(registry, "127.0.0.1", 0)?;
    let addr = server.local_addr();
    std::thread::spawn(move || {
        let _ = server.run();
    });
    Ok(addr)
}

fn shutdown_worker(addr: SocketAddr) {
    if let Ok(mut c) = Client::connect(addr) {
        let _ = c.set_read_timeout(Some(Duration::from_secs(2)));
        let _ = c.request(&Json::obj(vec![("op", Json::str("shutdown"))]));
    }
}

/// [`run`] with explicit measurement options (tests pass fast settings
/// directly instead of tunneling them through env vars).
pub fn run_with(scale: Scale, out: &Path, bench_opts: BenchOpts) -> Result<()> {
    // The rows measure distribution overhead and shard parallelism on a
    // fixed iteration budget, not convergence — small corpora suffice.
    let (dataset, k, iters) = match scale {
        Scale::Small => ("tiny-sparse", 8, 6),
        Scale::Paper => ("20news-small", 32, 15),
    };
    let mut cfg = RunConfig::default();
    cfg.dataset = dataset.to_string();
    cfg.engine = crate::config::EngineKind::FastHals;
    cfg.k = k;
    cfg.max_iters = iters;
    cfg.record_every = iters;
    cfg.threads = 2;
    cfg.seed = 42;

    println!("distributed training on {dataset} (k={k}, {iters} epochs, sync_every=2):\n");
    let mut rows = Vec::new();
    for &n in &WORKER_COUNTS {
        let workers: Vec<SocketAddr> =
            (0..n).map(|_| spawn_inproc_worker()).collect::<Result<_>>()?;
        let mut final_rel_error = f64::NAN;
        let s = measure(bench_opts, || {
            let opts =
                DistOpts { attach: workers.clone(), sync_every: 2, ..DistOpts::default() };
            let report = train_dist(&cfg, &opts).expect("train-dist bench run failed");
            final_rel_error = report.final_rel_error;
        });
        for &addr in &workers {
            shutdown_worker(addr);
        }
        let name = format!("dist_w{n}");
        println!("{}  [rel_error {final_rel_error:.4}]", row(&name, &s));
        rows.push(format!(
            "{dataset},{k},{iters},{name},{n},{:.6},{:.6},{:.6},{final_rel_error:.6}",
            s.median, s.min, s.max
        ));
    }
    let csv = out.join("train_dist.csv");
    write_csv(
        &csv,
        "dataset,k,iters,mode,workers,secs_median,secs_min,secs_max,final_rel_error",
        &rows,
    )?;
    println!("\nCSV: {}", csv.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_scaling_rows_for_every_worker_count() {
        let dir = std::env::temp_dir().join(format!("plnmf-distbench-{}", std::process::id()));
        run_with(Scale::Small, &dir, BenchOpts { warmup: 0, reps: 1 }).unwrap();
        let body = std::fs::read_to_string(dir.join("train_dist.csv")).unwrap();
        assert!(body.starts_with("dataset,k,iters,mode,workers"), "{body}");
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 1 + WORKER_COUNTS.len(), "{body}");
        for (i, n) in WORKER_COUNTS.iter().enumerate() {
            let line = lines[1 + i];
            assert!(line.contains(&format!(",dist_w{n},{n},")), "row w={n} missing: {body}");
            let secs: f64 = line.split(',').nth(5).unwrap().parse().unwrap();
            assert!(secs > 0.0, "unmeasured row: {line}");
            let err: f64 = line.split(',').nth(8).unwrap().parse().unwrap();
            assert!(err.is_finite() && err > 0.0 && err < 1.0, "bad rel_error: {line}");
        }
        std::fs::remove_dir_all(dir).ok();
    }
}

//! E1 / Fig. 6: elapsed time for a fixed iteration count as the tile
//! size T varies, for each dataset and K. The paper's claim: the curve is
//! U-shaped with its minimum at/near the model's T* (Eq. 11), because
//! data movement vol(T) (Eq. 9) is U-shaped.

use std::path::Path;
use std::sync::Arc;

use crate::data::load_dataset;
use crate::nmf::plnmf::PlNmfEngine;
use crate::nmf::{cost_model, NmfEngine};
use crate::parallel::{pool::default_threads, ThreadPool};
use crate::Result;

use super::report::write_csv;
use super::Scale;

#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub dataset: String,
    pub k: usize,
    pub tile: usize,
    pub secs_per_iter: f64,
    pub model_volume: f64,
    pub is_model_choice: bool,
}

/// The T sweep for a given K: powers-of-ish spread around the model
/// optimum, clamped to [1, K] (the paper sweeps 5..40).
pub fn tile_sweep(k: usize, cache_bytes: usize) -> Vec<usize> {
    let t_star = cost_model::select_tile(k, cache_bytes);
    let mut ts: Vec<usize> = vec![
        1,
        2,
        t_star / 2,
        t_star.saturating_sub(2),
        t_star,
        t_star + 2,
        t_star * 2,
        t_star * 4,
        k / 2,
        k,
    ];
    ts.retain(|&t| (1..=k).contains(&t));
    ts.sort_unstable();
    ts.dedup();
    ts
}

pub fn sweep(
    datasets: &[&str],
    ks: &[usize],
    iters: usize,
    cache_bytes: usize,
) -> Result<Vec<Fig6Row>> {
    let pool = Arc::new(ThreadPool::new(default_threads()));
    let mut rows = Vec::new();
    for &name in datasets {
        let ds = Arc::new(load_dataset(name, 42)?);
        for &k in ks {
            let t_star = cost_model::select_tile(k, cache_bytes);
            for t in tile_sweep(k, cache_bytes) {
                let mut engine = PlNmfEngine::new(ds.clone(), pool.clone(), k, 42, t, cache_bytes);
                // One untimed iteration to touch all buffers.
                engine.step()?;
                let timer = std::time::Instant::now();
                for _ in 0..iters {
                    engine.step()?;
                }
                let secs = timer.elapsed().as_secs_f64() / iters as f64;
                rows.push(Fig6Row {
                    dataset: name.to_string(),
                    k,
                    tile: t,
                    secs_per_iter: secs,
                    model_volume: cost_model::tiled_w_update_volume(
                        ds.v(),
                        k,
                        t,
                        cost_model::cache_words(cache_bytes),
                    ),
                    is_model_choice: t == t_star,
                });
                crate::info!("fig6 {name} K={k} T={t}: {secs:.4}s/iter");
            }
        }
    }
    Ok(rows)
}

pub fn render(rows: &[Fig6Row]) -> String {
    let mut out = String::from("Fig. 6 — time per iteration vs tile size (× = model's T*)\n");
    out.push_str(&format!(
        "{:<16} {:>4} {:>5} {:>12} {:>16}\n",
        "dataset", "K", "T", "s/iter", "model vol(T)"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:>4} {:>4}{} {:>12.4} {:>16.0}\n",
            r.dataset,
            r.k,
            r.tile,
            if r.is_model_choice { "×" } else { " " },
            r.secs_per_iter,
            r.model_volume
        ));
    }
    out
}

pub fn run(scale: Scale, out_dir: &Path) -> Result<()> {
    run_sel(scale, out_dir, &super::Selection::default())
}

pub fn run_sel(scale: Scale, out_dir: &Path, sel: &super::Selection) -> Result<()> {
    let iters = sel.iters.unwrap_or(match scale {
        Scale::Small => 10,
        Scale::Paper => 6,
    });
    let cache = 35 * 1024 * 1024;
    let rows = sweep(&sel.datasets(scale), &sel.ks(scale), iters, cache)?;
    print!("{}", render(&rows));
    let csv: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{:.6},{:.0},{}",
                r.dataset, r.k, r.tile, r.secs_per_iter, r.model_volume, r.is_model_choice
            )
        })
        .collect();
    write_csv(
        &out_dir.join("fig6_tile_size.csv"),
        "dataset,k,tile,secs_per_iter,model_volume,is_model_choice",
        &csv,
    )?;
    // Shape check: report whether the model's T is within 25% of the
    // empirical best for each (dataset, K).
    for (name, k) in rows.iter().map(|r| (r.dataset.clone(), r.k)).collect::<std::collections::BTreeSet<_>>() {
        let group: Vec<&Fig6Row> =
            rows.iter().filter(|r| r.dataset == name && r.k == k).collect();
        let best = group.iter().min_by(|a, b| a.secs_per_iter.total_cmp(&b.secs_per_iter)).unwrap();
        let model = group.iter().find(|r| r.is_model_choice);
        if let Some(m) = model {
            println!(
                "{name} K={k}: empirical best T={} ({:.4}s), model T={} ({:.4}s, +{:.0}%)",
                best.tile,
                best.secs_per_iter,
                m.tile,
                m.secs_per_iter,
                100.0 * (m.secs_per_iter / best.secs_per_iter - 1.0)
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_includes_model_choice_and_extremes() {
        let ts = tile_sweep(160, 35 << 20);
        assert!(ts.contains(&1));
        assert!(ts.contains(&160));
        assert!(ts.contains(&cost_model::select_tile(160, 35 << 20)));
        assert!(ts.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn tiny_sweep_runs() {
        let rows = sweep(&["tiny"], &[6], 2, 35 << 20).unwrap();
        assert!(rows.len() >= 3);
        assert!(rows.iter().any(|r| r.is_model_choice));
        assert!(rows.iter().all(|r| r.secs_per_iter > 0.0));
        assert!(render(&rows).contains("tiny"));
    }
}

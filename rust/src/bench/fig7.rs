//! E2 / Fig. 7 (+ E7 §6.3.2): relative error as a function of elapsed
//! time for every engine, per dataset and K — and the per-iteration
//! speedup of PL-NMF over naive FAST-HALS that §6.3.2 quotes
//! (3.07/3.06/5.81/3.02/3.07× at K=240).

use std::path::Path;

use crate::config::EngineKind;
use crate::coordinator::comparison::run_comparison;
use crate::coordinator::metrics::{summary_table, write_comparison_csv};
use crate::coordinator::RunReport;
use crate::Result;

use super::{bench_config, report::write_csv, Scale};

/// Engines in Fig. 7's legend order. XLA engines are included when their
/// artifacts exist (the comparison runner skips them gracefully).
pub fn fig7_engines() -> Vec<EngineKind> {
    vec![
        EngineKind::PlNmf,
        EngineKind::FastHals,
        EngineKind::Mu,
        EngineKind::Bpp,
        EngineKind::PlNmfXla,
        EngineKind::MuXla,
    ]
}

pub struct Fig7Output {
    pub reports: Vec<RunReport>,
    /// (dataset, k, plnmf s/iter, hals s/iter, speedup) — E7.
    pub per_iter_speedups: Vec<(String, usize, f64, f64, f64)>,
}

pub fn run_datasets(datasets: &[&str], ks: &[usize], scale: Scale) -> Result<Fig7Output> {
    run_datasets_iters(datasets, ks, scale, None)
}

pub fn run_datasets_iters(
    datasets: &[&str],
    ks: &[usize],
    scale: Scale,
    iters: Option<usize>,
) -> Result<Fig7Output> {
    run_datasets_engines(datasets, ks, scale, iters, &fig7_engines())
}

pub fn run_datasets_engines(
    datasets: &[&str],
    ks: &[usize],
    scale: Scale,
    iters: Option<usize>,
    engines: &[EngineKind],
) -> Result<Fig7Output> {
    let mut all_reports = Vec::new();
    let mut speedups = Vec::new();
    for &name in datasets {
        for &k in ks {
            let mut cfg = bench_config(name, k, scale);
            if let Some(it) = iters {
                cfg.max_iters = it;
            }
            let cmp = run_comparison(&cfg, engines)?;
            let plnmf = cmp.reports.iter().find(|r| r.engine == "plnmf-cpu");
            let hals = cmp.reports.iter().find(|r| r.engine == "fasthals-cpu");
            if let (Some(p), Some(h)) = (plnmf, hals) {
                speedups.push((
                    name.to_string(),
                    k,
                    p.secs_per_iter(),
                    h.secs_per_iter(),
                    h.secs_per_iter() / p.secs_per_iter().max(1e-12),
                ));
            }
            all_reports.extend(cmp.reports);
        }
    }
    Ok(Fig7Output { reports: all_reports, per_iter_speedups: speedups })
}

pub fn run(scale: Scale, out_dir: &Path) -> Result<()> {
    run_sel(scale, out_dir, &super::Selection::default())
}

pub fn run_sel(scale: Scale, out_dir: &Path, sel: &super::Selection) -> Result<()> {
    let out = run_datasets_engines(
        &sel.datasets(scale),
        &sel.ks(scale),
        scale,
        sel.iters,
        &sel.engines(fig7_engines()),
    )?;
    println!("Fig. 7 — relative error vs time (traces in CSV)\n");
    print!("{}", summary_table(&out.reports));
    write_comparison_csv(&out_dir.join("fig7_traces.csv"), &out.reports)?;

    println!("\n§6.3.2 — per-iteration speedup of PL-NMF over naive FAST-HALS");
    println!(
        "{:<16} {:>4} {:>12} {:>12} {:>9}",
        "dataset", "K", "plnmf s/it", "hals s/it", "speedup"
    );
    let mut csv = Vec::new();
    for (name, k, sp, sh, ratio) in &out.per_iter_speedups {
        println!("{name:<16} {k:>4} {sp:>12.4} {sh:>12.4} {ratio:>8.2}x");
        csv.push(format!("{name},{k},{sp:.6},{sh:.6},{ratio:.3}"));
    }
    write_csv(
        &out_dir.join("e7_per_iter_speedup.csv"),
        "dataset,k,plnmf_secs_per_iter,hals_secs_per_iter,speedup",
        &csv,
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_comparison_produces_speedups() {
        let out = run_datasets(&["tiny"], &[8], Scale::Small).unwrap();
        assert!(!out.reports.is_empty());
        assert_eq!(out.per_iter_speedups.len(), 1);
        let (_, _, sp, sh, ratio) = &out.per_iter_speedups[0];
        assert!(*sp > 0.0 && *sh > 0.0);
        assert!((*ratio - sh / sp).abs() < 1e-9);
    }
}

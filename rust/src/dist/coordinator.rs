//! The distributed-HALS coordinator behind `plnmf train-dist`.
//!
//! Topology: one coordinator process owning W (V×k) and the trace;
//! N training workers, each a `plnmf serve --train_worker` daemon
//! holding a row shard of Aᵀ (documents) and the matching rows of H.
//! Shards come from [`balanced_row_shards`] (nnz-balanced for sparse
//! data) so every sweep's critical path is the *heaviest* shard, not
//! the unluckiest.
//!
//! One epoch (= one FAST-HALS outer iteration):
//!
//! 1. broadcast W to every worker as a `0x04 sweep` frame;
//! 2. each worker runs its H half-sweep and replies `Q_s ‖ P_s (‖ H_s)`
//!    (`0x83 gram-response`);
//! 3. the coordinator all-reduces `Q = Σ Q_s` (k×k) and `P = Σ P_s`
//!    (V×k) in worker-index order — deterministic summation — then runs
//!    the W update and scores the epoch with
//!    [`error::rel_error_from_parts`], never touching the dataset.
//!
//! This is the MPI-FAUN communication shape: per epoch each worker
//! ships one V×k panel and one k×k Gram, independent of nnz.
//!
//! Fault tolerance: every `sync_every` epochs (and on the last) the
//! sweep returns the workers' H panels and the coordinator checkpoints
//! `(epoch, W, H panels)`. If any sweep fails — worker death, torn
//! connection, timeout — the coordinator respawns dead processes on
//! fresh ports, re-ships their shards, rewinds every survivor's H panel
//! to the checkpoint, truncates the trace, and resumes from
//! `checkpoint + 1`. A run with a mid-epoch worker kill therefore
//! completes, repeating at most `sync_every` epochs of work.

use std::net::SocketAddr;
use std::ops::Range;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{anyhow, bail, Context};

use crate::config::RunConfig;
use crate::coordinator::shard::balanced_row_shards;
use crate::coordinator::RunReport;
use crate::data::{load_dataset, DataMatrix, Dataset};
use crate::linalg::Mat;
use crate::nmf::halsops::{update_naive, UpdateKind};
use crate::nmf::{error, Factors, IterRecord, Solver};
use crate::parallel::pool::default_threads;
use crate::parallel::{split_even, ThreadPool};
use crate::serve::wire::{self, BinOp, WirePayload};
use crate::serve::worker::{probe_free_port, spawn_train_worker, wait_ready, ManagedWorker};
use crate::serve::Client;
use crate::util::json::Json;
use crate::util::{PhaseTimers, Timer};
use crate::{Elem, Result};

use super::protocol::{self, GramMeta, ShardBegin};

/// How the coordinator finds (or makes) its workers.
#[derive(Debug, Clone)]
pub struct DistOpts {
    /// The `plnmf` binary to exec for spawned workers
    /// (`std::env::current_exe()` from the CLI). Unused in attach mode.
    pub binary: Option<PathBuf>,
    /// Interface spawned workers bind / are dialed on.
    pub host: String,
    /// Worker count when spawning (capped at the document count).
    pub workers: usize,
    /// Checkpoint cadence: pull H panels every this many epochs.
    pub sync_every: usize,
    /// Give up after this many recoveries in one run.
    pub max_restarts: usize,
    /// Startup budget per spawned worker (bind + ready probe).
    pub ready_timeout: Duration,
    /// Attach to already-running daemons instead of spawning — one slot
    /// per address (in-process `Server::bind` in tests, or external
    /// fleets). No fault recovery: attached workers are not ours to
    /// restart, so a failed sweep is fatal.
    pub attach: Vec<SocketAddr>,
    /// Fault injection: kill worker `.1` at the start of epoch `.0`
    /// (spawned workers only) — exercises the recovery path end-to-end.
    pub chaos_kill: Option<(usize, usize)>,
}

impl Default for DistOpts {
    fn default() -> DistOpts {
        DistOpts {
            binary: None,
            host: "127.0.0.1".to_string(),
            workers: 2,
            sync_every: 4,
            max_restarts: 5,
            ready_timeout: Duration::from_secs(10),
            attach: Vec::new(),
            chaos_kill: None,
        }
    }
}

/// One worker slot: a shard assignment plus whatever process/connection
/// currently backs it. The slot (name, row range) is permanent; the
/// process and socket behind it change across restarts.
struct Slot {
    name: String,
    range: Range<usize>,
    addr: SocketAddr,
    child: Option<ManagedWorker>,
    client: Option<Client>,
}

/// One worker's sweep reply, decoded.
struct SweepReply {
    q: Mat,
    p: Mat,
    h: Option<Mat>,
}

/// Last consistent state the run can rewind to.
struct Checkpoint {
    epoch: usize,
    w: Mat,
    /// Per-slot H panels, indexed like `slots`.
    h: Vec<Mat>,
}

/// Rows `range` of the D×K matrix `h`, as an owned panel.
fn h_panel(h: &Mat, range: &Range<usize>) -> Mat {
    let k = h.cols();
    Mat::from_vec(range.len(), k, h.data()[range.start * k..range.end * k].to_vec())
}

fn add_into(acc: &mut Mat, x: &Mat) {
    assert_eq!((acc.rows(), acc.cols()), (x.rows(), x.cols()));
    for (a, &b) in acc.data_mut().iter_mut().zip(x.data()) {
        *a += b;
    }
}

/// Dial a worker and negotiate the binary protocol (training frames
/// need v2; a v1 peer cannot host shards).
fn connect(addr: SocketAddr) -> Result<Client> {
    let mut client =
        Client::connect(addr).with_context(|| format!("dialing train worker {addr}"))?;
    client.set_read_timeout(Some(Duration::from_secs(120)))?;
    let proto = client.negotiate()?;
    if proto < 2 {
        bail!("train worker {addr} only speaks protocol v{proto}; v2 is required");
    }
    Ok(client)
}

/// Send one `shard-load` frame and insist on an `ok` ack.
fn send_shard_load(
    client: &mut Client,
    name: &str,
    meta: &Json,
    rows: usize,
    cols: usize,
    data: &[Elem],
) -> Result<()> {
    let bytes = wire::encode(BinOp::ShardLoad, name, meta, rows, cols, data)?;
    let resp = client.request_wire(&WirePayload::Binary(bytes))?;
    match resp {
        WirePayload::Line(line) => {
            let j = Json::parse(line.trim())
                .map_err(|e| anyhow!("bad shard-load ack from '{name}': {e}"))?;
            if j.get("ok").as_bool() != Some(true) {
                bail!(
                    "worker refused shard-load for '{name}': {}",
                    j.get("error").as_str().unwrap_or(line.trim())
                );
            }
            Ok(())
        }
        WirePayload::Binary(_) => bail!("unexpected binary reply to shard-load for '{name}'"),
    }
}

/// Ship one slot's shard: `begin`, data chunks, then the H panel that
/// finalizes it (or re-syncs a resident shard) at `epoch`.
fn ship_shard(
    client: &mut Client,
    name: &str,
    range: &Range<usize>,
    ds: &Dataset,
    h: &Mat,
    k: usize,
    threads: usize,
    epoch: usize,
) -> Result<()> {
    let d_s = range.len();
    let v = ds.v();
    match &ds.at {
        DataMatrix::Sparse(at) => {
            let nnz = at.row_ptr()[range.end] - at.row_ptr()[range.start];
            let begin =
                ShardBegin { rows: d_s, cols: v, k, threads, sparse: true, row0: range.start, nnz };
            send_shard_load(client, name, &begin.to_meta(), 0, 0, &[])?;
            let mut seq = 0usize;
            let mut buf: Vec<(usize, usize, Elem)> = Vec::new();
            for row in range.clone() {
                let (cols, vals) = at.row(row);
                for (&c, &x) in cols.iter().zip(vals) {
                    buf.push((row - range.start, c as usize, x));
                }
                if buf.len() >= protocol::SPARSE_CHUNK_NNZ || (row + 1 == range.end && !buf.is_empty())
                {
                    let data = protocol::encode_triplets(&buf)?;
                    send_shard_load(client, name, &protocol::chunk_meta(seq), buf.len(), 3, &data)?;
                    seq += 1;
                    buf.clear();
                }
            }
        }
        DataMatrix::Dense(at) => {
            let begin = ShardBegin {
                rows: d_s,
                cols: v,
                k,
                threads,
                sparse: false,
                row0: range.start,
                nnz: d_s * v,
            };
            send_shard_load(client, name, &begin.to_meta(), 0, 0, &[])?;
            let step = protocol::dense_chunk_rows(v);
            let (mut seq, mut r0) = (0usize, range.start);
            while r0 < range.end {
                let r1 = (r0 + step).min(range.end);
                let data = &at.data()[r0 * v..r1 * v];
                send_shard_load(client, name, &protocol::chunk_meta(seq), r1 - r0, v, data)?;
                seq += 1;
                r0 = r1;
            }
        }
    }
    send_shard_load(client, name, &protocol::hpanel_meta(epoch), h.rows(), h.cols(), h.data())
}

/// One slot's epoch: broadcast W (with the run's H penalties riding the
/// sweep meta), collect and validate its gram-response.
fn sweep_slot(
    slot: &mut Slot,
    w: &Mat,
    epoch: usize,
    want_h: bool,
    k: usize,
    l1: f64,
    l2: f64,
) -> Result<SweepReply> {
    let name = slot.name.as_str();
    let client =
        slot.client.as_mut().ok_or_else(|| anyhow!("slot '{name}' has no live connection"))?;
    let bytes = wire::encode(
        BinOp::Sweep,
        name,
        &protocol::sweep_meta(epoch, want_h, l1, l2),
        w.rows(),
        k,
        w.data(),
    )?;
    let resp = client
        .request_wire(&WirePayload::Binary(bytes))
        .with_context(|| format!("sweep epoch {epoch} on '{name}'"))?;
    let frame = match resp {
        WirePayload::Binary(b) => wire::decode(&b)?,
        WirePayload::Line(line) => {
            let j = Json::parse(line.trim()).map_err(|e| anyhow!("bad sweep reply: {e}"))?;
            bail!(
                "worker '{name}' failed epoch {epoch}: {}",
                j.get("error").as_str().unwrap_or(line.trim())
            );
        }
    };
    if frame.op != BinOp::GramResp {
        bail!("worker '{name}' answered sweep with op {:?}", frame.op);
    }
    let gm = GramMeta::from_meta(&frame.meta)?;
    if gm.epoch != epoch {
        bail!("worker '{name}' answered epoch {} to a sweep for epoch {epoch}", gm.epoch);
    }
    let expect_h = if want_h { slot.range.len() } else { 0 };
    if frame.cols != k
        || gm.rows_q != k
        || gm.rows_p != w.rows()
        || gm.rows_h != expect_h
        || frame.rows != gm.rows_q + gm.rows_p + gm.rows_h
    {
        bail!(
            "worker '{name}' gram-response is misshapen: {}x{} with rows_q={} rows_p={} rows_h={}",
            frame.rows,
            frame.cols,
            gm.rows_q,
            gm.rows_p,
            gm.rows_h
        );
    }
    let (qk, pk) = (k * k, gm.rows_p * k);
    let q = Mat::from_vec(k, k, frame.data[..qk].to_vec());
    let p = Mat::from_vec(gm.rows_p, k, frame.data[qk..qk + pk].to_vec());
    let h = if want_h { Some(Mat::from_vec(gm.rows_h, k, frame.data[qk + pk..].to_vec())) } else { None };
    Ok(SweepReply { q, p, h })
}

/// Respawn dead workers, re-ship their shards, and rewind survivors'
/// H panels to the checkpoint. Every connection is rebuilt: a socket
/// that saw a failed epoch may hold a half-written frame.
fn recover(
    slots: &mut [Slot],
    opts: &DistOpts,
    ds: &Dataset,
    ckpt: &Checkpoint,
    k: usize,
    threads: usize,
) -> Result<()> {
    for (i, slot) in slots.iter_mut().enumerate() {
        slot.client = None;
        let dead = match slot.child.as_mut() {
            Some(child) => child.poll_exit().is_some(),
            None => false,
        };
        if dead {
            let binary = opts
                .binary
                .as_ref()
                .ok_or_else(|| anyhow!("train-dist: no worker binary to respawn with"))?;
            let port = probe_free_port(&opts.host)?;
            let mut child = spawn_train_worker(binary, &opts.host, port)?;
            wait_ready(&mut child, opts.ready_timeout)?;
            crate::info!(
                "train-dist: slot {i} respawned on {} (shard rows {}..{})",
                child.addr(),
                slot.range.start,
                slot.range.end
            );
            slot.addr = child.addr();
            slot.child = Some(child);
            let mut client = connect(slot.addr)?;
            ship_shard(&mut client, &slot.name, &slot.range, ds, &ckpt.h[i], k, threads, ckpt.epoch)?;
            slot.client = Some(client);
        } else {
            let mut client = connect(slot.addr)?;
            let h = &ckpt.h[i];
            send_shard_load(
                &mut client,
                &slot.name,
                &protocol::hpanel_meta(ckpt.epoch),
                h.rows(),
                h.cols(),
                h.data(),
            )?;
            slot.client = Some(client);
        }
    }
    Ok(())
}

/// Run distributed FAST-HALS per `cfg` over `opts`-described workers.
/// With one worker this reproduces `plnmf run --engine fasthals`
/// exactly: the same kernels run in the same order on the same pool
/// sizes, only split across two processes.
pub fn train_dist(cfg: &RunConfig, opts: &DistOpts) -> Result<RunReport> {
    cfg.validate()?;
    let spec = cfg.engine_spec()?;
    if spec.solver != Solver::Hals {
        bail!(
            "train-dist runs the distributed FAST-HALS engine; solver '{}' (loss '{}') is not \
             supported — use `plnmf run` for the mu/bpp families",
            spec.solver.name(),
            spec.loss.name()
        );
    }
    // H-side elastic-net penalties travel in every sweep meta; zero stays
    // off the wire so pre-spec workers see byte-identical frames.
    let (l1, l2) = (f64::from(spec.l1()), f64::from(spec.l2()));
    let ds = load_dataset(&cfg.dataset, cfg.seed)?;
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let pool = ThreadPool::new(threads);
    let k = cfg.k;
    let factors = Factors::init(&ds, k, cfg.seed, spec.init);

    let attach_mode = !opts.attach.is_empty();
    let want = if attach_mode { opts.attach.len() } else { opts.workers.max(1) };
    let nworkers = want.min(ds.d()).max(1);
    let ranges = match &ds.at {
        DataMatrix::Sparse(at) => balanced_row_shards(at, nworkers),
        DataMatrix::Dense(_) => split_even(ds.d(), nworkers),
    };
    crate::info!(
        "train-dist: {} worker(s) over '{}' ({} docs, k={}, sync_every={})",
        nworkers,
        cfg.dataset,
        ds.d(),
        k,
        opts.sync_every.max(1)
    );

    let mut slots: Vec<Slot> = Vec::with_capacity(nworkers);
    if attach_mode {
        for (i, (addr, range)) in opts.attach.iter().zip(ranges).enumerate() {
            slots.push(Slot { name: format!("train-{i}"), range, addr: *addr, child: None, client: None });
        }
    } else {
        let binary = opts
            .binary
            .as_ref()
            .ok_or_else(|| anyhow!("train-dist: no worker binary configured"))?;
        for (i, range) in ranges.into_iter().enumerate() {
            let port = probe_free_port(&opts.host)?;
            let mut child = spawn_train_worker(binary, &opts.host, port)?;
            wait_ready(&mut child, opts.ready_timeout)
                .with_context(|| format!("train worker {i} startup"))?;
            slots.push(Slot {
                name: format!("train-{i}"),
                range,
                addr: child.addr(),
                child: Some(child),
                client: None,
            });
        }
    }

    for slot in &mut slots {
        let mut client = connect(slot.addr)?;
        let h = h_panel(&factors.h, &slot.range);
        ship_shard(&mut client, &slot.name, &slot.range, &ds, &h, k, threads, 0)?;
        slot.client = Some(client);
    }

    let mut w = factors.w.clone();
    let mut ckpt = Checkpoint {
        epoch: 0,
        w: w.clone(),
        h: slots.iter().map(|s| h_panel(&factors.h, &s.range)).collect(),
    };
    let mut timers = PhaseTimers::new();
    let record_every = cfg.record_every.max(1);
    let sync_every = opts.sync_every.max(1);
    let iters = cfg.max_iters;
    let mut trace = vec![IterRecord {
        iter: 0,
        elapsed_secs: 0.0,
        rel_error: error::rel_error(&pool, &ds, &factors.w, &factors.h),
    }];
    let mut elapsed = 0.0f64;
    let mut restarts = 0usize;
    let mut chaos = opts.chaos_kill;

    let mut it = 1usize;
    while it <= iters {
        if let Some((epoch, idx)) = chaos {
            if epoch == it {
                chaos = None;
                if let Some(child) = slots.get_mut(idx).and_then(|s| s.child.as_mut()) {
                    crate::info!("train-dist: chaos kill of worker {idx} at epoch {it}");
                    child.kill();
                }
            }
        }
        let want_h = it % sync_every == 0 || it == iters;
        let t = Timer::start();
        let replies: Vec<Result<SweepReply>> = std::thread::scope(|scope| {
            let wref = &w;
            let handles: Vec<_> = slots
                .iter_mut()
                .map(|slot| scope.spawn(move || sweep_slot(slot, wref, it, want_h, k, l1, l2)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("sweep thread panicked"))))
                .collect()
        });
        if let Some(err) = replies.iter().find_map(|r| r.as_ref().err()) {
            restarts += 1;
            if attach_mode {
                bail!("train-dist: epoch {it} failed on attached workers: {err:#}");
            }
            if restarts > opts.max_restarts {
                bail!("train-dist: giving up after {} recoveries: {err:#}", restarts - 1);
            }
            crate::warn_!(
                "train-dist: epoch {it} failed ({err:#}); rewinding to epoch {}",
                ckpt.epoch
            );
            recover(&mut slots, opts, &ds, &ckpt, k, threads)?;
            w = ckpt.w.clone();
            trace.retain(|r| r.iter <= ckpt.epoch);
            it = ckpt.epoch + 1;
            continue;
        }
        let mut replies: Vec<SweepReply> =
            replies.into_iter().map(|r| r.expect("errors handled above")).collect();

        // All-reduce in slot order: Q = Σ Q_s, P = Σ P_s.
        let mut q = replies[0].q.clone();
        let mut p = replies[0].p.clone();
        for r in &replies[1..] {
            add_into(&mut q, &r.q);
            add_into(&mut p, &r.p);
        }
        update_naive(&pool, &mut w, &q, &p, UpdateKind::WithDiagAndNorm, &mut timers, "w_dmv");
        elapsed += t.elapsed_secs();

        if want_h {
            ckpt.epoch = it;
            ckpt.w = w.clone();
            for (i, r) in replies.iter_mut().enumerate() {
                ckpt.h[i] = r
                    .h
                    .take()
                    .ok_or_else(|| anyhow!("worker {i} omitted its H panel at sync epoch {it}"))?;
            }
        }
        if it % record_every == 0 || it == iters {
            trace.push(IterRecord {
                iter: it,
                elapsed_secs: elapsed,
                rel_error: error::rel_error_from_parts(&pool, ds.fro2, &p, &w, &q),
            });
            if cfg.tol > 0.0 && trace.len() > 5 {
                let prev = trace[trace.len() - 6].rel_error;
                let cur = trace[trace.len() - 1].rel_error;
                if prev - cur < cfg.tol {
                    break;
                }
            }
        }
        it += 1;
    }

    for slot in &mut slots {
        slot.client = None;
        if let Some(child) = slot.child.take() {
            child.shutdown(Duration::from_secs(2));
        }
    }

    let final_rel_error = trace.last().map(|r| r.rel_error).unwrap_or(f64::NAN);
    let report = RunReport {
        engine: "fasthals-dist",
        dataset: cfg.dataset.clone(),
        k,
        tile: cfg.tile,
        threads,
        trace,
        final_rel_error,
        total_step_secs: elapsed,
        timers,
    };
    if let Some(path) = &cfg.trace_path {
        crate::coordinator::metrics::write_trace_csv(std::path::Path::new(path), &report)?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::config::EngineKind;
    use crate::coordinator::Driver;
    use crate::serve::registry::{ModelRegistry, RegistryOpts};
    use crate::serve::Server;

    /// A zero-model in-process daemon — exactly what
    /// `plnmf serve --train_worker` runs, minus the process boundary.
    fn spawn_inproc_worker() -> SocketAddr {
        let registry = Arc::new(ModelRegistry::new(RegistryOpts::default()));
        let server = Server::bind(registry, "127.0.0.1", 0).unwrap();
        let addr = server.local_addr();
        std::thread::spawn(move || {
            let _ = server.run();
        });
        addr
    }

    fn dist_cfg(dataset: &str) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.dataset = dataset.into();
        cfg.engine = EngineKind::FastHals;
        cfg.k = 4;
        cfg.max_iters = 10;
        cfg.record_every = 1;
        cfg.threads = 2;
        cfg.seed = 7;
        cfg
    }

    fn shutdown_worker(addr: SocketAddr) {
        if let Ok(mut c) = Client::connect(addr) {
            let _ = c.set_read_timeout(Some(Duration::from_secs(2)));
            let _ = c.request(&Json::obj(vec![("op", Json::str("shutdown"))]));
        }
    }

    #[test]
    fn one_attached_worker_matches_single_process_trace() {
        for dataset in ["tiny", "tiny-sparse"] {
            let addr = spawn_inproc_worker();
            let cfg = dist_cfg(dataset);
            let opts = DistOpts { attach: vec![addr], sync_every: 3, ..DistOpts::default() };
            let dist = train_dist(&cfg, &opts).unwrap();
            let single = Driver::from_config(&cfg).unwrap().run().unwrap();
            shutdown_worker(addr);

            assert_eq!(dist.engine, "fasthals-dist");
            assert_eq!(
                dist.trace.len(),
                single.trace.len(),
                "{dataset}: trace lengths diverge"
            );
            for (d, s) in dist.trace.iter().zip(&single.trace) {
                assert_eq!(d.iter, s.iter, "{dataset}: iteration sequence diverges");
                assert!(
                    (d.rel_error - s.rel_error).abs() <= 2e-3,
                    "{dataset} iter {}: dist {} vs single {}",
                    d.iter,
                    d.rel_error,
                    s.rel_error
                );
            }
        }
    }

    #[test]
    fn two_attached_workers_converge_like_single_process() {
        for dataset in ["tiny", "tiny-sparse"] {
            let (a, b) = (spawn_inproc_worker(), spawn_inproc_worker());
            let cfg = dist_cfg(dataset);
            let opts = DistOpts { attach: vec![a, b], sync_every: 2, ..DistOpts::default() };
            let dist = train_dist(&cfg, &opts).unwrap();
            let single = Driver::from_config(&cfg).unwrap().run().unwrap();
            shutdown_worker(a);
            shutdown_worker(b);

            assert_eq!(dist.trace.len(), single.trace.len());
            for (d, s) in dist.trace.iter().zip(&single.trace) {
                assert_eq!(d.iter, s.iter);
                assert!(
                    (d.rel_error - s.rel_error).abs() <= 2e-3,
                    "{dataset} iter {}: dist {} vs single {}",
                    d.iter,
                    d.rel_error,
                    s.rel_error
                );
            }
            assert!(dist.final_rel_error.is_finite());
        }
    }

    #[test]
    fn regularized_nndsvda_run_matches_single_process_trace() {
        // Spec threading end-to-end: the sweep meta carries the H
        // penalties, the worker's regularized half-sweep mirrors the
        // engine's, and both processes start from the same NNDSVDa
        // factors — so the traces must line up like the free run's do.
        let addr = spawn_inproc_worker();
        let mut cfg = dist_cfg("tiny-sparse");
        cfg.alpha = 0.1;
        cfg.l1_ratio = 0.5;
        cfg.init = crate::nmf::Init::Nndsvda;
        let opts = DistOpts { attach: vec![addr], sync_every: 3, ..DistOpts::default() };
        let dist = train_dist(&cfg, &opts).unwrap();
        let single = Driver::from_config(&cfg).unwrap().run().unwrap();
        shutdown_worker(addr);

        assert_eq!(dist.trace.len(), single.trace.len(), "trace lengths diverge");
        for (d, s) in dist.trace.iter().zip(&single.trace) {
            assert_eq!(d.iter, s.iter);
            assert!(
                (d.rel_error - s.rel_error).abs() <= 2e-3,
                "iter {}: dist {} vs single {}",
                d.iter,
                d.rel_error,
                s.rel_error
            );
        }
    }

    #[test]
    fn non_hals_specs_are_rejected_before_any_worker_io() {
        // No binary, no attach list: the spec gate must fire before
        // train_dist ever tries to find a worker.
        let mut cfg = dist_cfg("tiny");
        cfg.engine = EngineKind::MuKl;
        let err = train_dist(&cfg, &DistOpts::default()).unwrap_err().to_string();
        assert!(err.contains("FAST-HALS"), "unexpected error: {err}");
    }

    #[test]
    fn two_slots_share_one_worker_process() {
        // Two shards resident in a single daemon's TrainStore, keyed by
        // job name — degenerate placement, same math.
        let addr = spawn_inproc_worker();
        let cfg = dist_cfg("tiny-sparse");
        let opts = DistOpts { attach: vec![addr, addr], sync_every: 3, ..DistOpts::default() };
        let dist = train_dist(&cfg, &opts).unwrap();
        let single = Driver::from_config(&cfg).unwrap().run().unwrap();
        shutdown_worker(addr);
        let d = dist.final_rel_error;
        let s = single.final_rel_error;
        assert!((d - s).abs() <= 2e-3, "shared-process dist {d} vs single {s}");
    }

    #[test]
    fn attach_mode_failure_is_fatal_not_retried() {
        // Attached worker that immediately goes away: train_dist must
        // error out (no restart authority over attached daemons).
        let addr = spawn_inproc_worker();
        shutdown_worker(addr);
        std::thread::sleep(Duration::from_millis(50));
        let cfg = dist_cfg("tiny");
        let opts = DistOpts { attach: vec![addr], ..DistOpts::default() };
        assert!(train_dist(&cfg, &opts).is_err());
    }

    #[test]
    fn h_panel_slices_rows() {
        let h = Mat::from_fn(5, 3, |i, j| (i * 3 + j) as Elem);
        let p = h_panel(&h, &(2..4));
        assert_eq!((p.rows(), p.cols()), (2, 3));
        assert_eq!(p.data(), &h.data()[6..12]);
    }

    #[test]
    fn add_into_sums_elementwise() {
        let mut a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![10.0, 20.0, 30.0, 40.0]);
        add_into(&mut a, &b);
        assert_eq!(a.data(), &[11.0, 22.0, 33.0, 44.0]);
    }
}

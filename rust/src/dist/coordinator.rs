//! The distributed-training coordinator behind `plnmf train-dist`.
//!
//! Topology: one coordinator process owning W (V×k) and the trace, and
//! a pr×pc **grid** of training workers, each a `plnmf serve
//! --train_worker` daemon. The default grid is 1×N — PR 6's row-sharded
//! plan, where each worker holds a row shard of Aᵀ (documents) and the
//! matching rows of H — and `--grid PRxPC` generalizes it: worker (i,j)
//! holds the A block at W-row-panel i × H-row-panel j plus the column's
//! H panel. Both axes are nnz-balanced by [`balanced_row_shards`] (the
//! document axis over Aᵀ, the word axis over A) so every round's
//! critical path is the *heaviest* block, not the unluckiest.
//!
//! ## 1D epochs (pr = 1)
//!
//! One epoch (= one FAST-HALS outer iteration):
//!
//! 1. broadcast W to every worker as a `0x04 sweep` frame (`0x06` for
//!    the MU/KL engines);
//! 2. each worker runs its H half-sweep and replies `Q_s ‖ P_s (‖ H_s)`
//!    (`0x83 gram-response`);
//! 3. the coordinator all-reduces `Q = Σ Q_s` (k×k) and `P = Σ P_s`
//!    (V×k) in worker-index order — deterministic summation — then runs
//!    the W update (HALS, MU, or the KL rule) and scores the epoch with
//!    [`error::rel_error_from_parts`], never touching the dataset.
//!
//! This is the MPI-FAUN communication shape: per epoch each worker
//! ships one V×k panel and one k×k Gram, independent of nnz.
//!
//! ## Grid epochs (pr > 1)
//!
//! With both factors panel-sharded no worker ever sees a full V×k
//! panel; an epoch is two rounds:
//!
//! 1. **Round A** (`0x07`): worker (i,j) receives its W row panel `W_i`
//!    (v_i×k) and replies `R_ij = A_ijᵀ·W_i` (d_j×k). The coordinator
//!    reduces `R_j = Σ_i R_ij` per column (grid-row order) and computes
//!    `S = WᵀW` itself.
//! 2. **Round B** (`0x08`): every worker in column j receives `S ‖ R_j`
//!    ((k+d_j)×k), runs the identical deterministic H update (so the
//!    pr replicas of `H_j` stay bit-identical), and replies its block
//!    product `P_ij = A_ij·H_j` (v_i×k); grid row 0 also answers
//!    `Q_j = H_jᵀH_j` (and the H panel at checkpoints). The coordinator
//!    reduces `Q = Σ_j Q_j`, assembles P from its row panels
//!    `P_i = Σ_j P_ij`, and updates W.
//!
//! Per-epoch coordinator traffic drops from `2·p·V·k` (1D broadcast +
//! gather) to `Σ v_i·k` out + `Σ (v_i + d_j)·k + pc·k²` back — panel
//! sized, not worker-count × V sized. (The KL loss needs the full W at
//! each worker and therefore stays on 1×N grids.)
//!
//! Shard shipping overlaps the first epoch: each slot's connection
//! ships its shard and immediately runs epoch 1's first frame on a
//! dedicated thread, so fast-loading workers are already sweeping while
//! big shards are still in flight.
//!
//! Fault tolerance: every `sync_every` epochs (and on the last) the
//! sweep returns the workers' H panels and the coordinator checkpoints
//! `(epoch, W, H panels)`. If any round fails — worker death, torn
//! connection, timeout — the coordinator respawns dead processes on
//! fresh ports, re-ships their shards (and only theirs), rewinds every
//! survivor's H panel to the checkpoint, truncates the trace, and
//! resumes from `checkpoint + 1`. A run with a mid-epoch worker kill
//! therefore completes, repeating at most `sync_every` epochs of work.

use std::net::SocketAddr;
use std::ops::Range;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{anyhow, bail, Context};

use crate::config::RunConfig;
use crate::coordinator::shard::balanced_row_shards;
use crate::coordinator::RunReport;
use crate::data::{load_dataset, DataMatrix, Dataset};
use crate::linalg::Mat;
use crate::nmf::halsops::{update_naive, Shrink, UpdateKind};
use crate::nmf::{error, mu, mukl, products, Factors, IterRecord, Loss, Solver};
use crate::parallel::pool::default_threads;
use crate::parallel::{split_even, ThreadPool};
use crate::serve::wire::{self, BinOp, WirePayload};
use crate::serve::worker::{probe_free_port, spawn_train_worker, wait_ready, ManagedWorker};
use crate::serve::Client;
use crate::util::json::Json;
use crate::util::{PhaseTimers, Timer};
use crate::{Elem, Result};

use super::protocol::{self, GramMeta, GridBReq, ShardBegin};

/// How the coordinator finds (or makes) its workers.
#[derive(Debug, Clone)]
pub struct DistOpts {
    /// The `plnmf` binary to exec for spawned workers
    /// (`std::env::current_exe()` from the CLI). Unused in attach mode.
    pub binary: Option<PathBuf>,
    /// Interface spawned workers bind / are dialed on.
    pub host: String,
    /// Worker count when spawning (capped at the document count).
    /// Ignored when `grid` is set — the grid dictates the count.
    pub workers: usize,
    /// Checkpoint cadence: pull H panels every this many epochs.
    pub sync_every: usize,
    /// Give up after this many recoveries in one run.
    pub max_restarts: usize,
    /// Startup budget per spawned worker (bind + ready probe).
    pub ready_timeout: Duration,
    /// Attach to already-running daemons instead of spawning — one slot
    /// per address (in-process `Server::bind` in tests, or external
    /// fleets). No fault recovery: attached workers are not ours to
    /// restart, so a failed sweep is fatal.
    pub attach: Vec<SocketAddr>,
    /// Fault injection: kill worker `.1` at the start of epoch `.0`
    /// (spawned workers only) — exercises the recovery path end-to-end.
    pub chaos_kill: Option<(usize, usize)>,
    /// The worker grid as `(pr, pc)` — pr W-row panels × pc H-row
    /// panels, `pr·pc` workers. `None` and `(1, n)` run the 1D
    /// row-sharded plan bit-identically.
    pub grid: Option<(usize, usize)>,
}

impl Default for DistOpts {
    fn default() -> DistOpts {
        DistOpts {
            binary: None,
            host: "127.0.0.1".to_string(),
            workers: 2,
            sync_every: 4,
            max_restarts: 5,
            ready_timeout: Duration::from_secs(10),
            attach: Vec::new(),
            chaos_kill: None,
            grid: None,
        }
    }
}

/// Coordinator-side accounting for one `train-dist` run — what the
/// bench prints beside the trace.
#[derive(Debug, Clone, Copy)]
pub struct DistStats {
    /// Worker slots the run used.
    pub workers: usize,
    /// The effective grid `(pr, pc)` after clamping to the dataset.
    pub grid: (usize, usize),
    /// Training epochs executed (recovered epochs count again — they
    /// were paid for again).
    pub epochs: usize,
    /// Bytes of per-epoch coordinator traffic (sweep/round frames in
    /// both directions; shard shipping excluded), summed over the run.
    pub coord_bytes: u64,
}

impl DistStats {
    /// Average per-epoch coordinator traffic in bytes.
    pub fn bytes_per_epoch(&self) -> u64 {
        if self.epochs == 0 {
            0
        } else {
            self.coord_bytes / self.epochs as u64
        }
    }
}

/// The 2D block partition behind a grid run: `pr` W-row (word) panels ×
/// `pc` H-row (document) panels, both nnz-balanced. Worker (i,j) owns
/// the A block `wrows[i] × hrows[j]`; since each axis is a contiguous
/// partition of its dimension, every matrix entry lands in exactly one
/// block (asserted by the plan property test).
#[derive(Debug, Clone)]
pub struct GridPlan {
    pub pr: usize,
    pub pc: usize,
    /// Word-axis panels (rows of A and of W), length `pr`.
    pub wrows: Vec<Range<usize>>,
    /// Document-axis panels (rows of Aᵀ and of H), length `pc`.
    pub hrows: Vec<Range<usize>>,
}

impl GridPlan {
    /// Partition `ds` over a pr×pc grid. Each axis is clamped to its
    /// dimension (a 4×4 grid over 3 documents becomes 4×3). With
    /// `pr = 1` the document axis is byte-identical to the 1D plan the
    /// row-sharded path computes.
    pub fn new(ds: &Dataset, pr: usize, pc: usize) -> GridPlan {
        let pr = pr.max(1).min(ds.v().max(1));
        let pc = pc.max(1).min(ds.d().max(1));
        let hrows = match &ds.at {
            DataMatrix::Sparse(at) => balanced_row_shards(at, pc),
            DataMatrix::Dense(_) => split_even(ds.d(), pc),
        };
        let wrows = match &ds.a {
            DataMatrix::Sparse(a) => balanced_row_shards(a, pr),
            DataMatrix::Dense(_) => split_even(ds.v(), pr),
        };
        GridPlan { pr, pc, wrows, hrows }
    }

    pub fn workers(&self) -> usize {
        self.pr * self.pc
    }

    /// The block owned by worker (i,j): word rows × document rows.
    pub fn block(&self, i: usize, j: usize) -> (Range<usize>, Range<usize>) {
        (self.wrows[i].clone(), self.hrows[j].clone())
    }
}

/// Which distributed engine a spec maps onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DistEngine {
    /// FAST-HALS — `0x04` sweeps, the PR 6 wire bit-for-bit.
    Hals,
    /// Frobenius multiplicative updates — `0x06` sweeps, same reply
    /// shape as HALS.
    Mu,
    /// KL multiplicative updates — `0x06` sweeps with the KL reply
    /// (colsum row + numerator partial). 1×N grids only.
    MuKl,
}

impl DistEngine {
    fn report_name(self) -> &'static str {
        match self {
            DistEngine::Hals => "fasthals-dist",
            DistEngine::Mu => "mu-dist",
            DistEngine::MuKl => "mukl-dist",
        }
    }
}

/// Map an engine spec onto the distributed families, or refuse with the
/// `plnmf run` pointer — before any worker I/O.
fn dist_engine(solver: Solver, loss: Loss) -> Result<DistEngine> {
    match (solver, loss) {
        (Solver::Hals, Loss::Frobenius) => Ok(DistEngine::Hals),
        (Solver::Mu, Loss::Frobenius) => Ok(DistEngine::Mu),
        (Solver::Mu, Loss::Kl) => Ok(DistEngine::MuKl),
        (solver, loss) => bail!(
            "train-dist runs the distributed FAST-HALS and MU engine families; solver '{}' \
             (loss '{}') is not supported — use `plnmf run` for the bpp family",
            solver.name(),
            loss.name()
        ),
    }
}

/// One worker slot: a shard assignment plus whatever process/connection
/// currently backs it. The slot (name, block) is permanent; the process
/// and socket behind it change across restarts. 1D slots leave the
/// word range covering all of V and sit at grid position (0, index).
struct Slot {
    name: String,
    /// Document rows (rows of Aᵀ / H) this slot owns.
    range: Range<usize>,
    /// Word rows (rows of A / W) this slot owns — `0..V` on 1D runs.
    vrange: Range<usize>,
    /// Grid position (i, j); 1D slots are (0, index).
    gi: usize,
    gj: usize,
    addr: SocketAddr,
    child: Option<ManagedWorker>,
    client: Option<Client>,
}

/// One worker's sweep reply, decoded. `q` is the k×k local Gram on the
/// Frobenius engines and the 1×k H column-sum row on KL.
struct SweepReply {
    q: Mat,
    p: Mat,
    h: Option<Mat>,
    /// Frame bytes this exchange moved (request + reply).
    bytes: u64,
}

/// One worker's grid round-A reply: its block partial `R_ij` (d_j×k).
struct GridAReply {
    r: Mat,
    bytes: u64,
}

/// One worker's grid round-B reply.
struct GridBReply {
    q: Option<Mat>,
    p: Mat,
    h: Option<Mat>,
    bytes: u64,
}

/// Last consistent state the run can rewind to. `h` is indexed per
/// slot on 1D runs and per grid *column* on grid runs.
struct Checkpoint {
    epoch: usize,
    w: Mat,
    h: Vec<Mat>,
}

/// Rows `range` of the D×K matrix `h`, as an owned panel.
fn h_panel(h: &Mat, range: &Range<usize>) -> Mat {
    let k = h.cols();
    Mat::from_vec(range.len(), k, h.data()[range.start * k..range.end * k].to_vec())
}

fn add_into(acc: &mut Mat, x: &Mat) {
    assert_eq!((acc.rows(), acc.cols()), (x.rows(), x.cols()));
    for (a, &b) in acc.data_mut().iter_mut().zip(x.data()) {
        *a += b;
    }
}

/// Dial a worker and negotiate the binary protocol (training frames
/// need v2; a v1 peer cannot host shards).
fn connect(addr: SocketAddr) -> Result<Client> {
    let mut client =
        Client::connect(addr).with_context(|| format!("dialing train worker {addr}"))?;
    client.set_read_timeout(Some(Duration::from_secs(120)))?;
    let proto = client.negotiate()?;
    if proto < 2 {
        bail!("train worker {addr} only speaks protocol v{proto}; v2 is required");
    }
    Ok(client)
}

/// Send one `shard-load` frame and insist on an `ok` ack.
fn send_shard_load(
    client: &mut Client,
    name: &str,
    meta: &Json,
    rows: usize,
    cols: usize,
    data: &[Elem],
) -> Result<()> {
    let bytes = wire::encode(BinOp::ShardLoad, name, meta, rows, cols, data)?;
    let resp = client.request_wire(&WirePayload::Binary(bytes))?;
    match resp {
        WirePayload::Line(line) => {
            let j = Json::parse(line.trim())
                .map_err(|e| anyhow!("bad shard-load ack from '{name}': {e}"))?;
            if j.get("ok").as_bool() != Some(true) {
                bail!(
                    "worker refused shard-load for '{name}': {}",
                    j.get("error").as_str().unwrap_or(line.trim())
                );
            }
            Ok(())
        }
        WirePayload::Binary(_) => bail!("unexpected binary reply to shard-load for '{name}'"),
    }
}

/// Ship one slot's block: `begin`, data chunks, then the H panel that
/// finalizes it (or re-syncs a resident shard) at `epoch`. 1D slots
/// pass `vrange = 0..V`, making this exactly the PR 6 row-shard wire;
/// grid slots additionally localize column indices into their word
/// panel.
fn ship_shard(
    client: &mut Client,
    name: &str,
    range: &Range<usize>,
    vrange: &Range<usize>,
    ds: &Dataset,
    h: &Mat,
    k: usize,
    threads: usize,
    epoch: usize,
) -> Result<()> {
    let d_s = range.len();
    let v_s = vrange.len();
    let whole_v = vrange.start == 0 && vrange.end == ds.v();
    match &ds.at {
        DataMatrix::Sparse(at) => {
            let nnz = if whole_v {
                at.row_ptr()[range.end] - at.row_ptr()[range.start]
            } else {
                let mut n = 0usize;
                for row in range.clone() {
                    let (cols, _) = at.row(row);
                    n += cols.iter().filter(|&&c| vrange.contains(&(c as usize))).count();
                }
                n
            };
            let begin = ShardBegin {
                rows: d_s,
                cols: v_s,
                k,
                threads,
                sparse: true,
                row0: range.start,
                nnz,
            };
            send_shard_load(client, name, &begin.to_meta(), 0, 0, &[])?;
            let mut seq = 0usize;
            let mut buf: Vec<(usize, usize, Elem)> = Vec::new();
            for row in range.clone() {
                let (cols, vals) = at.row(row);
                for (&c, &x) in cols.iter().zip(vals) {
                    let c = c as usize;
                    if vrange.contains(&c) {
                        buf.push((row - range.start, c - vrange.start, x));
                    }
                }
                if buf.len() >= protocol::SPARSE_CHUNK_NNZ || (row + 1 == range.end && !buf.is_empty())
                {
                    let data = protocol::encode_triplets(&buf)?;
                    send_shard_load(client, name, &protocol::chunk_meta(seq), buf.len(), 3, &data)?;
                    seq += 1;
                    buf.clear();
                }
            }
        }
        DataMatrix::Dense(at) => {
            let begin = ShardBegin {
                rows: d_s,
                cols: v_s,
                k,
                threads,
                sparse: false,
                row0: range.start,
                nnz: d_s * v_s,
            };
            send_shard_load(client, name, &begin.to_meta(), 0, 0, &[])?;
            let v = ds.v();
            let step = protocol::dense_chunk_rows(v_s);
            let (mut seq, mut r0) = (0usize, range.start);
            while r0 < range.end {
                let r1 = (r0 + step).min(range.end);
                if whole_v {
                    let data = &at.data()[r0 * v..r1 * v];
                    send_shard_load(client, name, &protocol::chunk_meta(seq), r1 - r0, v, data)?;
                } else {
                    let mut data = Vec::with_capacity((r1 - r0) * v_s);
                    for r in r0..r1 {
                        data.extend_from_slice(&at.data()[r * v + vrange.start..r * v + vrange.end]);
                    }
                    send_shard_load(client, name, &protocol::chunk_meta(seq), r1 - r0, v_s, &data)?;
                }
                seq += 1;
                r0 = r1;
            }
        }
    }
    send_shard_load(client, name, &protocol::hpanel_meta(epoch), h.rows(), h.cols(), h.data())
}

/// One 1D sweep round-trip on an already-connected client: broadcast W
/// through the engine's sweep op, collect and validate the
/// gram-response.
#[allow(clippy::too_many_arguments)]
fn sweep_client(
    client: &mut Client,
    name: &str,
    d_s: usize,
    w: &Mat,
    epoch: usize,
    want_h: bool,
    k: usize,
    l1: f64,
    l2: f64,
    engine: DistEngine,
) -> Result<SweepReply> {
    let (op, meta) = match engine {
        DistEngine::Hals => (BinOp::Sweep, protocol::sweep_meta(epoch, want_h, l1, l2)),
        DistEngine::Mu => (BinOp::SweepMu, protocol::sweep_mu_meta(epoch, want_h, false, l1, l2)),
        DistEngine::MuKl => (BinOp::SweepMu, protocol::sweep_mu_meta(epoch, want_h, true, l1, l2)),
    };
    let bytes = wire::encode(op, name, &meta, w.rows(), k, w.data())?;
    let sent = bytes.len() as u64;
    let resp = client
        .request_wire(&WirePayload::Binary(bytes))
        .with_context(|| format!("sweep epoch {epoch} on '{name}'"))?;
    let (frame, recvd) = match resp {
        WirePayload::Binary(b) => {
            let n = b.len() as u64;
            (wire::decode(&b)?, n)
        }
        WirePayload::Line(line) => {
            let j = Json::parse(line.trim()).map_err(|e| anyhow!("bad sweep reply: {e}"))?;
            bail!(
                "worker '{name}' failed epoch {epoch}: {}",
                j.get("error").as_str().unwrap_or(line.trim())
            );
        }
    };
    if frame.op != BinOp::GramResp {
        bail!("worker '{name}' answered sweep with op {:?}", frame.op);
    }
    let gm = GramMeta::from_meta(&frame.meta)?;
    if gm.epoch != epoch {
        bail!("worker '{name}' answered epoch {} to a sweep for epoch {epoch}", gm.epoch);
    }
    let expect_q = match engine {
        DistEngine::MuKl => 1,
        _ => k,
    };
    let expect_h = if want_h { d_s } else { 0 };
    if frame.cols != k
        || gm.rows_q != expect_q
        || gm.rows_p != w.rows()
        || gm.rows_h != expect_h
        || frame.rows != gm.rows_q + gm.rows_p + gm.rows_h
    {
        bail!(
            "worker '{name}' gram-response is misshapen: {}x{} with rows_q={} rows_p={} rows_h={}",
            frame.rows,
            frame.cols,
            gm.rows_q,
            gm.rows_p,
            gm.rows_h
        );
    }
    let (qk, pk) = (gm.rows_q * k, gm.rows_p * k);
    let q = Mat::from_vec(gm.rows_q, k, frame.data[..qk].to_vec());
    let p = Mat::from_vec(gm.rows_p, k, frame.data[qk..qk + pk].to_vec());
    let h = if want_h { Some(Mat::from_vec(gm.rows_h, k, frame.data[qk + pk..].to_vec())) } else { None };
    Ok(SweepReply { q, p, h, bytes: sent + recvd })
}

/// One slot's 1D epoch (see [`sweep_client`]).
fn sweep_slot(
    slot: &mut Slot,
    w: &Mat,
    epoch: usize,
    want_h: bool,
    k: usize,
    l1: f64,
    l2: f64,
    engine: DistEngine,
) -> Result<SweepReply> {
    let name = slot.name.clone();
    let d_s = slot.range.len();
    let client =
        slot.client.as_mut().ok_or_else(|| anyhow!("slot '{name}' has no live connection"))?;
    sweep_client(client, &name, d_s, w, epoch, want_h, k, l1, l2, engine)
}

/// One grid round-A round-trip: ship the slot's W panel, collect its
/// block partial `R_ij`.
fn grid_a_client(
    client: &mut Client,
    name: &str,
    wpanel: &Mat,
    epoch: usize,
    d_s: usize,
    k: usize,
) -> Result<GridAReply> {
    let bytes = wire::encode(
        BinOp::GridSweepA,
        name,
        &protocol::grid_a_meta(epoch),
        wpanel.rows(),
        k,
        wpanel.data(),
    )?;
    let sent = bytes.len() as u64;
    let resp = client
        .request_wire(&WirePayload::Binary(bytes))
        .with_context(|| format!("grid round A epoch {epoch} on '{name}'"))?;
    let (frame, recvd) = match resp {
        WirePayload::Binary(b) => {
            let n = b.len() as u64;
            (wire::decode(&b)?, n)
        }
        WirePayload::Line(line) => {
            let j = Json::parse(line.trim()).map_err(|e| anyhow!("bad round-A reply: {e}"))?;
            bail!(
                "worker '{name}' failed round A of epoch {epoch}: {}",
                j.get("error").as_str().unwrap_or(line.trim())
            );
        }
    };
    if frame.op != BinOp::GramResp {
        bail!("worker '{name}' answered round A with op {:?}", frame.op);
    }
    let gm = GramMeta::from_meta(&frame.meta)?;
    if gm.epoch != epoch {
        bail!("worker '{name}' answered epoch {} to round A of epoch {epoch}", gm.epoch);
    }
    if frame.cols != k || gm.rows_q != 0 || gm.rows_h != 0 || gm.rows_p != d_s || frame.rows != d_s
    {
        bail!(
            "worker '{name}' round-A reply is misshapen: {}x{} with rows_p={} (block holds {d_s} docs)",
            frame.rows,
            frame.cols,
            gm.rows_p
        );
    }
    Ok(GridAReply { r: Mat::from_vec(d_s, k, frame.data), bytes: sent + recvd })
}

/// One grid round-B round-trip: ship `S ‖ R_j`, collect
/// `[Q_j] ‖ P_ij (‖ H_j)`.
#[allow(clippy::too_many_arguments)]
fn grid_b_client(
    client: &mut Client,
    name: &str,
    s: &Mat,
    rj: &Mat,
    req: &GridBReq,
    v_s: usize,
    d_s: usize,
    k: usize,
) -> Result<GridBReply> {
    let mut data = Vec::with_capacity((k + d_s) * k);
    data.extend_from_slice(s.data());
    data.extend_from_slice(rj.data());
    let bytes = wire::encode(BinOp::GridSweepB, name, &protocol::grid_b_meta(req), k + d_s, k, &data)?;
    let sent = bytes.len() as u64;
    let epoch = req.epoch;
    let resp = client
        .request_wire(&WirePayload::Binary(bytes))
        .with_context(|| format!("grid round B epoch {epoch} on '{name}'"))?;
    let (frame, recvd) = match resp {
        WirePayload::Binary(b) => {
            let n = b.len() as u64;
            (wire::decode(&b)?, n)
        }
        WirePayload::Line(line) => {
            let j = Json::parse(line.trim()).map_err(|e| anyhow!("bad round-B reply: {e}"))?;
            bail!(
                "worker '{name}' failed round B of epoch {epoch}: {}",
                j.get("error").as_str().unwrap_or(line.trim())
            );
        }
    };
    if frame.op != BinOp::GramResp {
        bail!("worker '{name}' answered round B with op {:?}", frame.op);
    }
    let gm = GramMeta::from_meta(&frame.meta)?;
    if gm.epoch != epoch {
        bail!("worker '{name}' answered epoch {} to round B of epoch {epoch}", gm.epoch);
    }
    let expect_q = if req.want_q { k } else { 0 };
    let expect_h = if req.want_h { d_s } else { 0 };
    if frame.cols != k
        || gm.rows_q != expect_q
        || gm.rows_p != v_s
        || gm.rows_h != expect_h
        || frame.rows != gm.rows_q + gm.rows_p + gm.rows_h
    {
        bail!(
            "worker '{name}' round-B reply is misshapen: {}x{} with rows_q={} rows_p={} rows_h={}",
            frame.rows,
            frame.cols,
            gm.rows_q,
            gm.rows_p,
            gm.rows_h
        );
    }
    let (qk, pk) = (gm.rows_q * k, gm.rows_p * k);
    let q = if req.want_q { Some(Mat::from_vec(k, k, frame.data[..qk].to_vec())) } else { None };
    let p = Mat::from_vec(v_s, k, frame.data[qk..qk + pk].to_vec());
    let h = if req.want_h {
        Some(Mat::from_vec(d_s, k, frame.data[qk + pk..].to_vec()))
    } else {
        None
    };
    Ok(GridBReply { q, p, h, bytes: sent + recvd })
}

/// Respawn dead workers, re-ship their shards, and rewind survivors'
/// H panels to the checkpoint. Every connection is rebuilt: a socket
/// that saw a failed epoch may hold a half-written frame. The
/// checkpoint's H panel for a slot is `ckpt.h[slot.gj]` — per-slot on
/// 1D runs (where `gj` is the slot index), per-column on grids (the pr
/// replicas of a column rewind to the same panel).
fn recover(
    slots: &mut [Slot],
    opts: &DistOpts,
    ds: &Dataset,
    ckpt: &Checkpoint,
    k: usize,
    threads: usize,
) -> Result<()> {
    for (i, slot) in slots.iter_mut().enumerate() {
        slot.client = None;
        let h = &ckpt.h[slot.gj];
        let dead = match slot.child.as_mut() {
            Some(child) => child.poll_exit().is_some(),
            None => false,
        };
        if dead {
            let binary = opts
                .binary
                .as_ref()
                .ok_or_else(|| anyhow!("train-dist: no worker binary to respawn with"))?;
            let port = probe_free_port(&opts.host)?;
            let mut child = spawn_train_worker(binary, &opts.host, port)?;
            wait_ready(&mut child, opts.ready_timeout)?;
            crate::info!(
                "train-dist: slot {i} respawned on {} (block docs {}..{} words {}..{})",
                child.addr(),
                slot.range.start,
                slot.range.end,
                slot.vrange.start,
                slot.vrange.end
            );
            slot.addr = child.addr();
            slot.child = Some(child);
            let mut client = connect(slot.addr)?;
            ship_shard(
                &mut client,
                &slot.name,
                &slot.range,
                &slot.vrange,
                ds,
                h,
                k,
                threads,
                ckpt.epoch,
            )?;
            slot.client = Some(client);
        } else {
            let mut client = connect(slot.addr)?;
            send_shard_load(
                &mut client,
                &slot.name,
                &protocol::hpanel_meta(ckpt.epoch),
                h.rows(),
                h.cols(),
                h.data(),
            )?;
            slot.client = Some(client);
        }
    }
    Ok(())
}

/// Build the slot list: attach to the given addresses or spawn one
/// worker process per slot.
fn make_slots(
    opts: &DistOpts,
    blocks: Vec<(String, Range<usize>, Range<usize>, usize, usize)>,
) -> Result<Vec<Slot>> {
    let mut slots: Vec<Slot> = Vec::with_capacity(blocks.len());
    if !opts.attach.is_empty() {
        if opts.attach.len() != blocks.len() {
            bail!(
                "train-dist: {} attached worker(s) for {} slot(s) — the plan needs one address \
                 per slot",
                opts.attach.len(),
                blocks.len()
            );
        }
        for (addr, (name, range, vrange, gi, gj)) in opts.attach.iter().zip(blocks) {
            slots.push(Slot { name, range, vrange, gi, gj, addr: *addr, child: None, client: None });
        }
    } else {
        let binary = opts
            .binary
            .as_ref()
            .ok_or_else(|| anyhow!("train-dist: no worker binary configured"))?;
        for (i, (name, range, vrange, gi, gj)) in blocks.into_iter().enumerate() {
            let port = probe_free_port(&opts.host)?;
            let mut child = spawn_train_worker(binary, &opts.host, port)?;
            wait_ready(&mut child, opts.ready_timeout)
                .with_context(|| format!("train worker {i} startup"))?;
            slots.push(Slot {
                name,
                range,
                vrange,
                gi,
                gj,
                addr: child.addr(),
                child: Some(child),
                client: None,
            });
        }
    }
    Ok(slots)
}

/// Drain the slot list: drop connections, shut spawned workers down.
fn shutdown_slots(slots: &mut [Slot]) {
    for slot in slots {
        slot.client = None;
        if let Some(child) = slot.child.take() {
            child.shutdown(Duration::from_secs(2));
        }
    }
}

/// Run distributed training per `cfg` over `opts`-described workers.
/// With one worker this reproduces the matching `plnmf run` engine
/// exactly: the same kernels run in the same order on the same pool
/// sizes, only split across two processes.
pub fn train_dist(cfg: &RunConfig, opts: &DistOpts) -> Result<RunReport> {
    Ok(train_dist_with_stats(cfg, opts)?.0)
}

/// [`train_dist`], also returning the coordinator's [`DistStats`]
/// (bench + tooling surface).
pub fn train_dist_with_stats(cfg: &RunConfig, opts: &DistOpts) -> Result<(RunReport, DistStats)> {
    cfg.validate()?;
    let spec = cfg.engine_spec()?;
    let engine = dist_engine(spec.solver, spec.loss)?;
    // H-side elastic-net penalties travel in every sweep meta; zero stays
    // off the wire so pre-spec workers see byte-identical frames.
    let (l1, l2) = (f64::from(spec.l1()), f64::from(spec.l2()));
    let ds = load_dataset(&cfg.dataset, cfg.seed)?;
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let pool = ThreadPool::new(threads);
    let k = cfg.k;
    let factors = Factors::init(&ds, k, cfg.seed, spec.init);
    let pr = opts.grid.map_or(1, |(pr, _)| pr).max(1).min(ds.v().max(1));
    if pr > 1 {
        if engine == DistEngine::MuKl {
            bail!(
                "train-dist --grid with pr > 1 cannot run the KL loss (the KL H half-step needs \
                 the full W at every worker); use a 1xN grid or the frobenius loss"
            );
        }
        run_grid(cfg, opts, engine, (l1, l2), &ds, &pool, factors, threads)
    } else {
        run_1d(cfg, opts, engine, (l1, l2), &ds, &pool, factors, threads)
    }
}

/// The 1×N row-sharded epoch loop — the PR 6 plan, now engine-generic.
#[allow(clippy::too_many_arguments)]
fn run_1d(
    cfg: &RunConfig,
    opts: &DistOpts,
    engine: DistEngine,
    (l1, l2): (f64, f64),
    ds: &Dataset,
    pool: &ThreadPool,
    factors: Factors,
    threads: usize,
) -> Result<(RunReport, DistStats)> {
    let k = cfg.k;
    let attach_mode = !opts.attach.is_empty();
    let want = if attach_mode {
        opts.attach.len()
    } else {
        opts.grid.map_or(opts.workers, |(_, pc)| pc).max(1)
    };
    let nworkers = want.min(ds.d()).max(1);
    if let Some((gr, gc)) = opts.grid {
        if attach_mode && gr.max(1) * gc.max(1) != opts.attach.len() {
            bail!(
                "train-dist: grid {}x{} needs {} worker(s), {} attached",
                gr,
                gc,
                gr.max(1) * gc.max(1),
                opts.attach.len()
            );
        }
    }
    let ranges = match &ds.at {
        DataMatrix::Sparse(at) => balanced_row_shards(at, nworkers),
        DataMatrix::Dense(_) => split_even(ds.d(), nworkers),
    };
    crate::info!(
        "train-dist: {} worker(s) over '{}' ({} docs, k={}, engine={}, sync_every={})",
        nworkers,
        cfg.dataset,
        ds.d(),
        k,
        engine.report_name(),
        opts.sync_every.max(1)
    );

    let v_all = 0..ds.v();
    let blocks: Vec<_> = ranges
        .into_iter()
        .enumerate()
        .map(|(i, range)| (format!("train-{i}"), range, v_all.clone(), 0, i))
        .collect();
    let mut slots = make_slots(opts, blocks)?;

    let record_every = cfg.record_every.max(1);
    let sync_every = opts.sync_every.max(1);
    let iters = cfg.max_iters;
    // want_h: checkpoint panels at sync epochs; the KL engine also needs
    // panels at record epochs (its trace is scored from an assembled H —
    // there are no Frobenius parts to score from).
    let want_h_at = |it: usize| {
        let sync = it % sync_every == 0 || it == iters;
        let record = it % record_every == 0 || it == iters;
        sync || (engine == DistEngine::MuKl && record)
    };

    // Ship every shard — and overlap: each slot's thread ships on its
    // own connection and immediately runs epoch 1's sweep, so a worker
    // with a small shard is already sweeping while big shards are still
    // in flight. (Skipped when chaos wants to kill inside epoch 1: the
    // kill must precede the frames.)
    let do_prefetch = iters >= 1 && opts.chaos_kill.map_or(true, |(e, _)| e != 1);
    let shipped: Vec<Result<(Client, Option<Result<SweepReply>>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = slots
            .iter()
            .map(|slot| {
                let name = slot.name.clone();
                let range = slot.range.clone();
                let addr = slot.addr;
                let h = h_panel(&factors.h, &slot.range);
                let wref = &factors.w;
                scope.spawn(move || -> Result<(Client, Option<Result<SweepReply>>)> {
                    let mut client = connect(addr)?;
                    ship_shard(&mut client, &name, &range, &(0..ds.v()), ds, &h, k, threads, 0)?;
                    if !do_prefetch {
                        return Ok((client, None));
                    }
                    let first = sweep_client(
                        &mut client,
                        &name,
                        range.len(),
                        wref,
                        1,
                        want_h_at(1),
                        k,
                        l1,
                        l2,
                        engine,
                    );
                    Ok((client, Some(first)))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("ship thread panicked"))))
            .collect()
    });
    let mut prefetched: Option<Vec<Result<SweepReply>>> =
        if do_prefetch { Some(Vec::with_capacity(slots.len())) } else { None };
    for (slot, r) in slots.iter_mut().zip(shipped) {
        let (client, first) = r.with_context(|| format!("shipping shard to '{}'", slot.name))?;
        slot.client = Some(client);
        if let (Some(list), Some(first)) = (prefetched.as_mut(), first) {
            list.push(first);
        }
    }

    let mut w = factors.w.clone();
    let mut ckpt = Checkpoint {
        epoch: 0,
        w: w.clone(),
        h: slots.iter().map(|s| h_panel(&factors.h, &s.range)).collect(),
    };
    let mut timers = PhaseTimers::new();
    let mut trace = vec![IterRecord {
        iter: 0,
        elapsed_secs: 0.0,
        rel_error: error::rel_error(pool, ds, &factors.w, &factors.h),
    }];
    let mut elapsed = 0.0f64;
    let mut restarts = 0usize;
    let mut chaos = opts.chaos_kill;
    let mut coord_bytes = 0u64;
    let mut epochs_run = 0usize;

    let mut it = 1usize;
    while it <= iters {
        if let Some((epoch, idx)) = chaos {
            if epoch == it {
                chaos = None;
                if let Some(child) = slots.get_mut(idx).and_then(|s| s.child.as_mut()) {
                    crate::info!("train-dist: chaos kill of worker {idx} at epoch {it}");
                    child.kill();
                }
            }
        }
        let want_h = want_h_at(it);
        let sync = it % sync_every == 0 || it == iters;
        let record = it % record_every == 0 || it == iters;
        let t = Timer::start();
        let replies: Vec<Result<SweepReply>> = match prefetched.take() {
            Some(r) if it == 1 => r,
            _ => std::thread::scope(|scope| {
                let wref = &w;
                let handles: Vec<_> = slots
                    .iter_mut()
                    .map(|slot| {
                        scope.spawn(move || {
                            sweep_slot(slot, wref, it, want_h, k, l1, l2, engine)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("sweep thread panicked"))))
                    .collect()
            }),
        };
        if let Some(err) = replies.iter().find_map(|r| r.as_ref().err()) {
            restarts += 1;
            if attach_mode {
                bail!("train-dist: epoch {it} failed on attached workers: {err:#}");
            }
            if restarts > opts.max_restarts {
                bail!("train-dist: giving up after {} recoveries: {err:#}", restarts - 1);
            }
            crate::warn_!(
                "train-dist: epoch {it} failed ({err:#}); rewinding to epoch {}",
                ckpt.epoch
            );
            recover(&mut slots, opts, ds, &ckpt, k, threads)?;
            w = ckpt.w.clone();
            trace.retain(|r| r.iter <= ckpt.epoch);
            it = ckpt.epoch + 1;
            continue;
        }
        let mut replies: Vec<SweepReply> =
            replies.into_iter().map(|r| r.expect("errors handled above")).collect();
        coord_bytes += replies.iter().map(|r| r.bytes).sum::<u64>();
        epochs_run += 1;

        // All-reduce in slot order: Q = Σ Q_s, P = Σ P_s — then the
        // engine's W half-step on the reduced parts.
        let mut q = replies[0].q.clone();
        let mut p = replies[0].p.clone();
        for r in &replies[1..] {
            add_into(&mut q, &r.q);
            add_into(&mut p, &r.p);
        }
        match engine {
            DistEngine::Hals => {
                update_naive(pool, &mut w, &q, &p, UpdateKind::WithDiagAndNorm, &mut timers, "w_dmv");
            }
            DistEngine::Mu => {
                timers.time("w_mu", || mu::mu_update(pool, &mut w, &q, &p));
            }
            DistEngine::MuKl => {
                // The KL denominator is colsum(H); reduce the workers'
                // colsum rows in f64, slot order (q here is 1×k rows).
                let mut denom = vec![0.0f64; k];
                for r in &replies {
                    for (t, d) in denom.iter_mut().enumerate() {
                        *d += f64::from(r.q.data()[t]);
                    }
                }
                timers.time("w_mukl", || mukl::kl_apply(pool, &mut w, &p, &denom, Shrink::NONE));
            }
        }
        elapsed += t.elapsed_secs();

        if record {
            let rel = if engine == DistEngine::MuKl {
                // No Frobenius parts to score from — assemble H and
                // score directly (what the single-process trace records).
                let mut hdata = vec![0.0 as Elem; ds.d() * k];
                for (slot, r) in slots.iter().zip(&replies) {
                    let h = r.h.as_ref().ok_or_else(|| {
                        anyhow!("worker '{}' omitted its H panel at record epoch {it}", slot.name)
                    })?;
                    hdata[slot.range.start * k..slot.range.end * k].copy_from_slice(h.data());
                }
                let hfull = Mat::from_vec(ds.d(), k, hdata);
                error::rel_error(pool, ds, &w, &hfull)
            } else {
                error::rel_error_from_parts(pool, ds.fro2, &p, &w, &q)
            };
            trace.push(IterRecord { iter: it, elapsed_secs: elapsed, rel_error: rel });
            if cfg.tol > 0.0 && trace.len() > 5 {
                let prev = trace[trace.len() - 6].rel_error;
                let cur = trace[trace.len() - 1].rel_error;
                if prev - cur < cfg.tol {
                    if sync {
                        ckpt.epoch = it;
                    }
                    break;
                }
            }
        }
        if sync {
            ckpt.epoch = it;
            ckpt.w = w.clone();
            for (i, r) in replies.iter_mut().enumerate() {
                ckpt.h[i] = r
                    .h
                    .take()
                    .ok_or_else(|| anyhow!("worker {i} omitted its H panel at sync epoch {it}"))?;
            }
        }
        it += 1;
    }

    shutdown_slots(&mut slots);

    let final_rel_error = trace.last().map(|r| r.rel_error).unwrap_or(f64::NAN);
    let report = RunReport {
        engine: engine.report_name(),
        dataset: cfg.dataset.clone(),
        k,
        tile: cfg.tile,
        threads,
        trace,
        final_rel_error,
        total_step_secs: elapsed,
        timers,
    };
    if let Some(path) = &cfg.trace_path {
        crate::coordinator::metrics::write_trace_csv(std::path::Path::new(path), &report)?;
    }
    let stats = DistStats { workers: nworkers, grid: (1, nworkers), epochs: epochs_run, coord_bytes };
    Ok((report, stats))
}

/// The pr×pc two-round epoch loop (see the module doc).
#[allow(clippy::too_many_arguments)]
fn run_grid(
    cfg: &RunConfig,
    opts: &DistOpts,
    engine: DistEngine,
    (l1, l2): (f64, f64),
    ds: &Dataset,
    pool: &ThreadPool,
    factors: Factors,
    threads: usize,
) -> Result<(RunReport, DistStats)> {
    let k = cfg.k;
    let (gr, gc) = opts.grid.expect("run_grid is only entered with a grid");
    let plan = GridPlan::new(ds, gr, gc);
    let (pr, pc) = (plan.pr, plan.pc);
    let attach_mode = !opts.attach.is_empty();
    crate::info!(
        "train-dist: {}x{} grid ({} workers) over '{}' ({}x{} entries, k={}, engine={})",
        pr,
        pc,
        plan.workers(),
        cfg.dataset,
        ds.v(),
        ds.d(),
        k,
        engine.report_name()
    );

    // Slots in row-major grid order: slot i*pc + j is worker (i, j).
    let blocks: Vec<_> = (0..pr)
        .flat_map(|i| (0..pc).map(move |j| (i, j)))
        .map(|(i, j)| {
            let (vrange, drange) = plan.block(i, j);
            (format!("train-g{i}-{j}"), drange, vrange, i, j)
        })
        .collect();
    let mut slots = make_slots(opts, blocks)?;

    let record_every = cfg.record_every.max(1);
    let sync_every = opts.sync_every.max(1);
    let iters = cfg.max_iters;

    // Ship every block, overlapping with epoch 1's round A exactly like
    // the 1D path overlaps its first sweep.
    let do_prefetch = iters >= 1 && opts.chaos_kill.map_or(true, |(e, _)| e != 1);
    let shipped: Vec<Result<(Client, Option<Result<GridAReply>>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = slots
            .iter()
            .map(|slot| {
                let name = slot.name.clone();
                let drange = slot.range.clone();
                let vrange = slot.vrange.clone();
                let addr = slot.addr;
                let h = h_panel(&factors.h, &slot.range);
                let wp = h_panel(&factors.w, &slot.vrange);
                scope.spawn(move || -> Result<(Client, Option<Result<GridAReply>>)> {
                    let mut client = connect(addr)?;
                    ship_shard(&mut client, &name, &drange, &vrange, ds, &h, k, threads, 0)?;
                    if !do_prefetch {
                        return Ok((client, None));
                    }
                    let first = grid_a_client(&mut client, &name, &wp, 1, drange.len(), k);
                    Ok((client, Some(first)))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("ship thread panicked"))))
            .collect()
    });
    let mut prefetched: Option<Vec<Result<GridAReply>>> =
        if do_prefetch { Some(Vec::with_capacity(slots.len())) } else { None };
    for (slot, r) in slots.iter_mut().zip(shipped) {
        let (client, first) = r.with_context(|| format!("shipping block to '{}'", slot.name))?;
        slot.client = Some(client);
        if let (Some(list), Some(first)) = (prefetched.as_mut(), first) {
            list.push(first);
        }
    }

    let mut w = factors.w.clone();
    let mut ckpt = Checkpoint {
        epoch: 0,
        w: w.clone(),
        h: plan.hrows.iter().map(|r| h_panel(&factors.h, r)).collect(),
    };
    let mut timers = PhaseTimers::new();
    let mut trace = vec![IterRecord {
        iter: 0,
        elapsed_secs: 0.0,
        rel_error: error::rel_error(pool, ds, &factors.w, &factors.h),
    }];
    let mut elapsed = 0.0f64;
    let mut restarts = 0usize;
    let mut chaos = opts.chaos_kill;
    let mut coord_bytes = 0u64;
    let mut epochs_run = 0usize;

    // One failure handler for both rounds: attach mode is fatal,
    // spawn mode rewinds to the checkpoint.
    macro_rules! fail_epoch {
        ($it:ident, $err:expr) => {{
            let err = $err;
            restarts += 1;
            if attach_mode {
                bail!("train-dist: epoch {} failed on attached workers: {err:#}", $it);
            }
            if restarts > opts.max_restarts {
                bail!("train-dist: giving up after {} recoveries: {err:#}", restarts - 1);
            }
            crate::warn_!(
                "train-dist: epoch {} failed ({err:#}); rewinding to epoch {}",
                $it,
                ckpt.epoch
            );
            recover(&mut slots, opts, ds, &ckpt, k, threads)?;
            w = ckpt.w.clone();
            trace.retain(|r| r.iter <= ckpt.epoch);
            $it = ckpt.epoch + 1;
            continue;
        }};
    }

    let mut it = 1usize;
    while it <= iters {
        if let Some((epoch, idx)) = chaos {
            if epoch == it {
                chaos = None;
                if let Some(child) = slots.get_mut(idx).and_then(|s| s.child.as_mut()) {
                    crate::info!("train-dist: chaos kill of worker {idx} at epoch {it}");
                    child.kill();
                }
            }
        }
        let sync = it % sync_every == 0 || it == iters;
        let record = it % record_every == 0 || it == iters;
        let t = Timer::start();

        // Round A: W panels out, block partials R_ij back.
        let ra: Vec<Result<GridAReply>> = match prefetched.take() {
            Some(r) if it == 1 => r,
            _ => std::thread::scope(|scope| {
                let wref = &w;
                let handles: Vec<_> = slots
                    .iter_mut()
                    .map(|slot| {
                        let wp = h_panel(wref, &slot.vrange);
                        scope.spawn(move || {
                            let name = slot.name.clone();
                            let d_s = slot.range.len();
                            let client = slot
                                .client
                                .as_mut()
                                .ok_or_else(|| anyhow!("slot '{name}' has no live connection"))?;
                            grid_a_client(client, &name, &wp, it, d_s, k)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("round-A thread panicked"))))
                    .collect()
            }),
        };
        if let Some(err) = ra.iter().find_map(|r| r.as_ref().err()) {
            fail_epoch!(it, err);
        }
        let ra: Vec<GridAReply> = ra.into_iter().map(|r| r.expect("errors handled above")).collect();
        let bytes_a = ra.iter().map(|r| r.bytes).sum::<u64>();

        // Column reduce in grid-row order: R_j = Σ_i R_ij; the k×k Gram
        // S = WᵀW is the coordinator's own half of round B's input.
        let mut rj: Vec<Mat> = Vec::with_capacity(pc);
        for j in 0..pc {
            let mut acc = ra[j].r.clone();
            for i in 1..pr {
                add_into(&mut acc, &ra[i * pc + j].r);
            }
            rj.push(acc);
        }
        let s = products::factor_gram(pool, &w);

        // Round B: S ‖ R_j out, [Q_j] ‖ P_ij (‖ H_j) back. Grid row 0
        // answers the per-column Gram and checkpoint panels; the other
        // rows hold bit-identical H_j replicas and ship only P_ij.
        let rb: Vec<Result<GridBReply>> = std::thread::scope(|scope| {
            let (sref, rjref) = (&s, &rj);
            let handles: Vec<_> = slots
                .iter_mut()
                .map(|slot| {
                    let req = GridBReq {
                        epoch: it,
                        mu: engine == DistEngine::Mu,
                        want_q: slot.gi == 0,
                        want_h: sync && slot.gi == 0,
                        l1,
                        l2,
                    };
                    scope.spawn(move || {
                        let name = slot.name.clone();
                        let (v_s, d_s) = (slot.vrange.len(), slot.range.len());
                        let gj = slot.gj;
                        let client = slot
                            .client
                            .as_mut()
                            .ok_or_else(|| anyhow!("slot '{name}' has no live connection"))?;
                        grid_b_client(client, &name, sref, &rjref[gj], &req, v_s, d_s, k)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("round-B thread panicked"))))
                .collect()
        });
        if let Some(err) = rb.iter().find_map(|r| r.as_ref().err()) {
            fail_epoch!(it, err);
        }
        let mut rb: Vec<GridBReply> =
            rb.into_iter().map(|r| r.expect("errors handled above")).collect();
        coord_bytes += bytes_a + rb.iter().map(|r| r.bytes).sum::<u64>();
        epochs_run += 1;

        // Reduce: Q = Σ_j Q_j (grid-column order), P assembled from its
        // row panels P_i = Σ_j P_ij (also column order per panel).
        let mut q = rb[0].q.clone().expect("grid row 0 answers the Gram");
        for j in 1..pc {
            add_into(&mut q, rb[j].q.as_ref().expect("grid row 0 answers the Gram"));
        }
        let mut pdata = vec![0.0 as Elem; ds.v() * k];
        for i in 0..pr {
            let mut panel = rb[i * pc].p.clone();
            for j in 1..pc {
                add_into(&mut panel, &rb[i * pc + j].p);
            }
            let vrange = &plan.wrows[i];
            pdata[vrange.start * k..vrange.end * k].copy_from_slice(panel.data());
        }
        let p = Mat::from_vec(ds.v(), k, pdata);
        match engine {
            DistEngine::Hals => {
                update_naive(pool, &mut w, &q, &p, UpdateKind::WithDiagAndNorm, &mut timers, "w_dmv");
            }
            DistEngine::Mu => {
                timers.time("w_mu", || mu::mu_update(pool, &mut w, &q, &p));
            }
            DistEngine::MuKl => unreachable!("KL is rejected before the grid path"),
        }
        elapsed += t.elapsed_secs();

        if record {
            trace.push(IterRecord {
                iter: it,
                elapsed_secs: elapsed,
                rel_error: error::rel_error_from_parts(pool, ds.fro2, &p, &w, &q),
            });
            if cfg.tol > 0.0 && trace.len() > 5 {
                let prev = trace[trace.len() - 6].rel_error;
                let cur = trace[trace.len() - 1].rel_error;
                if prev - cur < cfg.tol {
                    break;
                }
            }
        }
        if sync {
            ckpt.epoch = it;
            ckpt.w = w.clone();
            for j in 0..pc {
                ckpt.h[j] = rb[j]
                    .h
                    .take()
                    .ok_or_else(|| anyhow!("column {j} omitted its H panel at sync epoch {it}"))?;
            }
        }
        it += 1;
    }

    shutdown_slots(&mut slots);

    let final_rel_error = trace.last().map(|r| r.rel_error).unwrap_or(f64::NAN);
    let report = RunReport {
        engine: engine.report_name(),
        dataset: cfg.dataset.clone(),
        k,
        tile: cfg.tile,
        threads,
        trace,
        final_rel_error,
        total_step_secs: elapsed,
        timers,
    };
    if let Some(path) = &cfg.trace_path {
        crate::coordinator::metrics::write_trace_csv(std::path::Path::new(path), &report)?;
    }
    let stats =
        DistStats { workers: plan.workers(), grid: (pr, pc), epochs: epochs_run, coord_bytes };
    Ok((report, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::config::EngineKind;
    use crate::coordinator::Driver;
    use crate::serve::registry::{ModelRegistry, RegistryOpts};
    use crate::serve::Server;
    use crate::sparse::Csr;

    /// A zero-model in-process daemon — exactly what
    /// `plnmf serve --train_worker` runs, minus the process boundary.
    fn spawn_inproc_worker() -> SocketAddr {
        let registry = Arc::new(ModelRegistry::new(RegistryOpts::default()));
        let server = Server::bind(registry, "127.0.0.1", 0).unwrap();
        let addr = server.local_addr();
        std::thread::spawn(move || {
            let _ = server.run();
        });
        addr
    }

    fn dist_cfg(dataset: &str) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.dataset = dataset.into();
        cfg.engine = EngineKind::FastHals;
        cfg.k = 4;
        cfg.max_iters = 10;
        cfg.record_every = 1;
        cfg.threads = 2;
        cfg.seed = 7;
        cfg
    }

    fn shutdown_worker(addr: SocketAddr) {
        if let Ok(mut c) = Client::connect(addr) {
            let _ = c.set_read_timeout(Some(Duration::from_secs(2)));
            let _ = c.request(&Json::obj(vec![("op", Json::str("shutdown"))]));
        }
    }

    fn assert_traces_close(dist: &RunReport, single: &RunReport, label: &str) {
        assert_eq!(dist.trace.len(), single.trace.len(), "{label}: trace lengths diverge");
        for (d, s) in dist.trace.iter().zip(&single.trace) {
            assert_eq!(d.iter, s.iter, "{label}: iteration sequence diverges");
            assert!(
                (d.rel_error - s.rel_error).abs() <= 2e-3,
                "{label} iter {}: dist {} vs single {}",
                d.iter,
                d.rel_error,
                s.rel_error
            );
        }
    }

    #[test]
    fn one_attached_worker_matches_single_process_trace() {
        for dataset in ["tiny", "tiny-sparse"] {
            let addr = spawn_inproc_worker();
            let cfg = dist_cfg(dataset);
            let opts = DistOpts { attach: vec![addr], sync_every: 3, ..DistOpts::default() };
            let dist = train_dist(&cfg, &opts).unwrap();
            let single = Driver::from_config(&cfg).unwrap().run().unwrap();
            shutdown_worker(addr);

            assert_eq!(dist.engine, "fasthals-dist");
            assert_traces_close(&dist, &single, dataset);
        }
    }

    #[test]
    fn two_attached_workers_converge_like_single_process() {
        for dataset in ["tiny", "tiny-sparse"] {
            let (a, b) = (spawn_inproc_worker(), spawn_inproc_worker());
            let cfg = dist_cfg(dataset);
            let opts = DistOpts { attach: vec![a, b], sync_every: 2, ..DistOpts::default() };
            let dist = train_dist(&cfg, &opts).unwrap();
            let single = Driver::from_config(&cfg).unwrap().run().unwrap();
            shutdown_worker(a);
            shutdown_worker(b);

            assert_traces_close(&dist, &single, dataset);
            assert!(dist.final_rel_error.is_finite());
        }
    }

    #[test]
    fn regularized_nndsvda_run_matches_single_process_trace() {
        // Spec threading end-to-end: the sweep meta carries the H
        // penalties, the worker's regularized half-sweep mirrors the
        // engine's, and both processes start from the same NNDSVDa
        // factors — so the traces must line up like the free run's do.
        let addr = spawn_inproc_worker();
        let mut cfg = dist_cfg("tiny-sparse");
        cfg.alpha = 0.1;
        cfg.l1_ratio = 0.5;
        cfg.init = crate::nmf::Init::Nndsvda;
        let opts = DistOpts { attach: vec![addr], sync_every: 3, ..DistOpts::default() };
        let dist = train_dist(&cfg, &opts).unwrap();
        let single = Driver::from_config(&cfg).unwrap().run().unwrap();
        shutdown_worker(addr);

        assert_traces_close(&dist, &single, "regularized");
    }

    #[test]
    fn mu_engine_matches_single_process_trace() {
        // The 0x06 sweep: one worker runs the exact MU kernels the
        // in-process engine runs, split across the wire.
        for dataset in ["tiny", "tiny-sparse"] {
            let addr = spawn_inproc_worker();
            let mut cfg = dist_cfg(dataset);
            cfg.engine = EngineKind::Mu;
            let opts = DistOpts { attach: vec![addr], sync_every: 3, ..DistOpts::default() };
            let dist = train_dist(&cfg, &opts).unwrap();
            let single = Driver::from_config(&cfg).unwrap().run().unwrap();
            shutdown_worker(addr);

            assert_eq!(dist.engine, "mu-dist");
            assert_traces_close(&dist, &single, dataset);
        }
    }

    #[test]
    fn kl_engine_matches_single_process_trace() {
        // The KL variant of the 0x06 sweep: the worker ships colsum and
        // numerator partials, the coordinator applies the W rule, and
        // record epochs score an assembled H — the trace must still
        // track the in-process MU-KL engine.
        let addr = spawn_inproc_worker();
        let mut cfg = dist_cfg("tiny-sparse");
        cfg.engine = EngineKind::MuKl;
        let opts = DistOpts { attach: vec![addr], sync_every: 3, ..DistOpts::default() };
        let dist = train_dist(&cfg, &opts).unwrap();
        let single = Driver::from_config(&cfg).unwrap().run().unwrap();
        shutdown_worker(addr);

        assert_eq!(dist.engine, "mukl-dist");
        assert_traces_close(&dist, &single, "kl");
    }

    #[test]
    fn grid_2x2_matches_single_process_trace() {
        // The tentpole: a 2x2 grid (4 workers, both factors
        // panel-sharded) must track the single-process FAST-HALS trace
        // exactly like the 1D plan does.
        for dataset in ["tiny", "tiny-sparse"] {
            let addrs: Vec<_> = (0..4).map(|_| spawn_inproc_worker()).collect();
            let cfg = dist_cfg(dataset);
            let opts = DistOpts {
                attach: addrs.clone(),
                sync_every: 3,
                grid: Some((2, 2)),
                ..DistOpts::default()
            };
            let (dist, stats) = train_dist_with_stats(&cfg, &opts).unwrap();
            let single = Driver::from_config(&cfg).unwrap().run().unwrap();
            for addr in addrs {
                shutdown_worker(addr);
            }

            assert_eq!(dist.engine, "fasthals-dist");
            assert_eq!(stats.grid, (2, 2));
            assert_eq!(stats.workers, 4);
            assert!(stats.coord_bytes > 0);
            assert_traces_close(&dist, &single, dataset);
        }
    }

    #[test]
    fn grid_2x2_runs_the_mu_engine_too() {
        let addrs: Vec<_> = (0..4).map(|_| spawn_inproc_worker()).collect();
        let mut cfg = dist_cfg("tiny-sparse");
        cfg.engine = EngineKind::Mu;
        let opts = DistOpts {
            attach: addrs.clone(),
            sync_every: 3,
            grid: Some((2, 2)),
            ..DistOpts::default()
        };
        let dist = train_dist(&cfg, &opts).unwrap();
        let single = Driver::from_config(&cfg).unwrap().run().unwrap();
        for addr in addrs {
            shutdown_worker(addr);
        }
        assert_eq!(dist.engine, "mu-dist");
        assert_traces_close(&dist, &single, "grid-mu");
    }

    #[test]
    fn grid_with_pr_1_is_bit_identical_to_the_1d_plan() {
        // `--grid 1x2` must route through the row-sharded path verbatim
        // — same frames, same kernels, bitwise-equal trace.
        let cfg = dist_cfg("tiny-sparse");
        let (a, b) = (spawn_inproc_worker(), spawn_inproc_worker());
        let opts_1d = DistOpts { attach: vec![a, b], sync_every: 3, ..DistOpts::default() };
        let flat = train_dist(&cfg, &opts_1d).unwrap();
        shutdown_worker(a);
        shutdown_worker(b);

        let (c, d) = (spawn_inproc_worker(), spawn_inproc_worker());
        let opts_grid = DistOpts {
            attach: vec![c, d],
            sync_every: 3,
            grid: Some((1, 2)),
            ..DistOpts::default()
        };
        let (grid, stats) = train_dist_with_stats(&cfg, &opts_grid).unwrap();
        shutdown_worker(c);
        shutdown_worker(d);

        assert_eq!(stats.grid, (1, 2));
        assert_eq!(flat.trace.len(), grid.trace.len());
        for (f, g) in flat.trace.iter().zip(&grid.trace) {
            assert_eq!(f.iter, g.iter);
            assert_eq!(
                f.rel_error.to_bits(),
                g.rel_error.to_bits(),
                "iter {}: 1D {} vs pr=1 grid {}",
                f.iter,
                f.rel_error,
                g.rel_error
            );
        }
    }

    #[test]
    fn grid_per_epoch_bytes_sit_below_the_1d_plan_at_equal_workers() {
        // The tentpole's communication claim, measured on real frames:
        // a 2x2 grid moves strictly fewer coordinator bytes per epoch
        // than 4 row shards.
        let cfg = dist_cfg("tiny-sparse");
        let addrs: Vec<_> = (0..4).map(|_| spawn_inproc_worker()).collect();
        let opts = DistOpts { attach: addrs.clone(), sync_every: 3, ..DistOpts::default() };
        let (_, flat) = train_dist_with_stats(&cfg, &opts).unwrap();
        for addr in addrs {
            shutdown_worker(addr);
        }

        let addrs: Vec<_> = (0..4).map(|_| spawn_inproc_worker()).collect();
        let opts = DistOpts {
            attach: addrs.clone(),
            sync_every: 3,
            grid: Some((2, 2)),
            ..DistOpts::default()
        };
        let (_, grid) = train_dist_with_stats(&cfg, &opts).unwrap();
        for addr in addrs {
            shutdown_worker(addr);
        }

        assert_eq!(flat.workers, grid.workers);
        assert!(
            grid.bytes_per_epoch() < flat.bytes_per_epoch(),
            "grid {} B/epoch vs 1D {} B/epoch",
            grid.bytes_per_epoch(),
            flat.bytes_per_epoch()
        );
    }

    #[test]
    fn unsupported_specs_are_rejected_before_any_worker_io() {
        // No binary, no attach list: the spec gate must fire before
        // train_dist ever tries to find a worker.
        let mut cfg = dist_cfg("tiny");
        cfg.engine = EngineKind::Bpp;
        let err = train_dist(&cfg, &DistOpts::default()).unwrap_err().to_string();
        assert!(err.contains("not supported"), "unexpected error: {err}");
    }

    #[test]
    fn kl_on_a_wide_grid_is_rejected_before_any_worker_io() {
        let mut cfg = dist_cfg("tiny-sparse");
        cfg.engine = EngineKind::MuKl;
        let opts = DistOpts { grid: Some((2, 2)), ..DistOpts::default() };
        let err = train_dist(&cfg, &opts).unwrap_err().to_string();
        assert!(err.contains("KL"), "unexpected error: {err}");
    }

    #[test]
    fn two_slots_share_one_worker_process() {
        // Two shards resident in a single daemon's TrainStore, keyed by
        // job name — degenerate placement, same math.
        let addr = spawn_inproc_worker();
        let cfg = dist_cfg("tiny-sparse");
        let opts = DistOpts { attach: vec![addr, addr], sync_every: 3, ..DistOpts::default() };
        let dist = train_dist(&cfg, &opts).unwrap();
        let single = Driver::from_config(&cfg).unwrap().run().unwrap();
        shutdown_worker(addr);
        let d = dist.final_rel_error;
        let s = single.final_rel_error;
        assert!((d - s).abs() <= 2e-3, "shared-process dist {d} vs single {s}");
    }

    #[test]
    fn grid_slots_can_share_one_worker_process_too() {
        // All four 2x2 blocks resident in one daemon — unique job names
        // keep the TrainStore entries apart.
        let addr = spawn_inproc_worker();
        let cfg = dist_cfg("tiny-sparse");
        let opts = DistOpts {
            attach: vec![addr; 4],
            sync_every: 3,
            grid: Some((2, 2)),
            ..DistOpts::default()
        };
        let dist = train_dist(&cfg, &opts).unwrap();
        let single = Driver::from_config(&cfg).unwrap().run().unwrap();
        shutdown_worker(addr);
        assert!((dist.final_rel_error - single.final_rel_error).abs() <= 2e-3);
    }

    #[test]
    fn attach_mode_failure_is_fatal_not_retried() {
        // Attached worker that immediately goes away: train_dist must
        // error out (no restart authority over attached daemons).
        let addr = spawn_inproc_worker();
        shutdown_worker(addr);
        std::thread::sleep(Duration::from_millis(50));
        let cfg = dist_cfg("tiny");
        let opts = DistOpts { attach: vec![addr], ..DistOpts::default() };
        assert!(train_dist(&cfg, &opts).is_err());
    }

    #[test]
    fn attach_count_must_match_the_grid() {
        let cfg = dist_cfg("tiny");
        let addr = spawn_inproc_worker();
        let opts =
            DistOpts { attach: vec![addr], grid: Some((2, 2)), ..DistOpts::default() };
        let err = train_dist(&cfg, &opts).unwrap_err().to_string();
        shutdown_worker(addr);
        assert!(err.contains("4"), "unexpected error: {err}");
    }

    #[test]
    fn h_panel_slices_rows() {
        let h = Mat::from_fn(5, 3, |i, j| (i * 3 + j) as Elem);
        let p = h_panel(&h, &(2..4));
        assert_eq!((p.rows(), p.cols()), (2, 3));
        assert_eq!(p.data(), &h.data()[6..12]);
    }

    #[test]
    fn add_into_sums_elementwise() {
        let mut a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![10.0, 20.0, 30.0, 40.0]);
        add_into(&mut a, &b);
        assert_eq!(a.data(), &[11.0, 22.0, 33.0, 44.0]);
    }

    // ---- GridPlan properties -------------------------------------------

    /// A deterministic synthetic sparse dataset (xorshift-seeded) so the
    /// plan properties range over shapes the named profiles don't cover.
    fn synth_dataset(v: usize, d: usize, nnz: usize, seed: u64) -> Dataset {
        let mut state = seed | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let mut dense = Mat::from_vec(v, d, vec![0.0; v * d]);
        for _ in 0..nnz {
            let (i, j) = ((next() as usize) % v, (next() as usize) % d);
            let x = ((next() % 97) + 1) as Elem / 97.0;
            dense.data_mut()[i * d + j] = x;
        }
        let a = DataMatrix::Sparse(Csr::from_dense(&dense));
        let at = a.transposed();
        let fro2 = a.fro2();
        let profile = crate::config::DatasetProfile {
            name: "synth",
            kind: crate::config::DatasetKind::SparseText,
            v,
            d,
            nnz: a.nnz(),
            zipf_s: 0.0,
            planted_rank: 0,
            paper_stats: None,
        };
        Dataset { profile, a, at, fro2 }
    }

    fn assert_partitions(ranges: &[Range<usize>], n: usize, label: &str) {
        assert!(!ranges.is_empty(), "{label}: empty partition");
        assert_eq!(ranges[0].start, 0, "{label}: must start at 0");
        assert_eq!(ranges.last().unwrap().end, n, "{label}: must end at {n}");
        for w in ranges.windows(2) {
            assert_eq!(w[0].end, w[1].start, "{label}: gap or overlap at {:?}", w);
        }
    }

    #[test]
    fn grid_plan_covers_every_entry_exactly_once_and_balances_nnz() {
        // Contiguous partitions on both axes ⇒ every (row, col) lands in
        // exactly one block; nnz balance within the shard planner's
        // guarantee (≤ even share + heaviest single row).
        for (v, d, nnz, seed) in
            [(17, 31, 60, 1u64), (64, 24, 300, 2), (9, 9, 81, 3), (120, 7, 500, 4)]
        {
            let ds = synth_dataset(v, d, nnz, seed);
            for (pr, pc) in [(1, 1), (1, 3), (2, 2), (3, 2), (4, 5), (200, 200)] {
                let plan = GridPlan::new(&ds, pr, pc);
                assert!(plan.pr <= v && plan.pc <= d, "clamped to the dataset");
                assert_eq!(plan.wrows.len(), plan.pr);
                assert_eq!(plan.hrows.len(), plan.pc);
                assert_partitions(&plan.wrows, v, "wrows");
                assert_partitions(&plan.hrows, d, "hrows");
                let area: usize = (0..plan.pr)
                    .flat_map(|i| (0..plan.pc).map(move |j| (i, j)))
                    .map(|(i, j)| {
                        let (vr, dr) = plan.block(i, j);
                        vr.len() * dr.len()
                    })
                    .sum();
                assert_eq!(area, v * d, "blocks must tile the matrix");

                let (a, at) = match (&ds.a, &ds.at) {
                    (DataMatrix::Sparse(a), DataMatrix::Sparse(at)) => (a, at),
                    _ => unreachable!(),
                };
                for (csr, ranges, parts) in
                    [(a, &plan.wrows, plan.pr), (at, &plan.hrows, plan.pc)]
                {
                    let total = csr.nnz();
                    let heaviest = (0..csr.rows())
                        .map(|r| csr.row_ptr()[r + 1] - csr.row_ptr()[r])
                        .max()
                        .unwrap_or(0);
                    let cap = total / parts + heaviest;
                    for r in ranges.iter() {
                        let n = csr.row_ptr()[r.end] - csr.row_ptr()[r.start];
                        assert!(n <= cap, "shard {r:?} holds {n} nnz, cap {cap}");
                    }
                }
            }
        }
    }

    #[test]
    fn grid_plan_degenerates_to_the_1d_plans_on_either_axis() {
        let ds = synth_dataset(40, 25, 200, 9);
        let (a, at) = match (&ds.a, &ds.at) {
            (DataMatrix::Sparse(a), DataMatrix::Sparse(at)) => (a, at),
            _ => unreachable!(),
        };
        for pc in [1, 2, 5] {
            let plan = GridPlan::new(&ds, 1, pc);
            assert_eq!(plan.wrows, vec![0..ds.v()]);
            assert_eq!(plan.hrows, balanced_row_shards(at, pc), "pc={pc}: 1D doc plan");
        }
        for pr in [1, 3, 4] {
            let plan = GridPlan::new(&ds, pr, 1);
            assert_eq!(plan.hrows, vec![0..ds.d()]);
            assert_eq!(plan.wrows, balanced_row_shards(a, pr), "pr={pr}: 1D word plan");
        }
    }
}

//! Worker-resident training state and the `shard-load` / `sweep`
//! handlers the serve daemon dispatches to.
//!
//! A training worker is an ordinary `plnmf serve` process (started with
//! `--train_worker`, i.e. zero serving models) whose [`TrainStore`]
//! hosts, per job name, a resident dataset shard plus factor panels —
//! the training analogue of the registry keeping factors and Grams hot
//! across serving requests. One `sweep` performs the worker's half of a
//! FAST-HALS iteration on its shard:
//!
//! ```text
//! R_s = A_sᵀ·W          (d_s×k, the local SpMM/GEMM)
//! H_s ← hals_update(H_s, WᵀW, R_s)      (the H half-sweep)
//! P_s = A_s·H_s         (V×k partial product)
//! Q_s = H_sᵀH_s         (k×k local Gram)
//! ```
//!
//! and replies `Q_s ‖ P_s (‖ H_s)`; the coordinator all-reduces the
//! partials and runs the W update — the 1D-partitioned alternating
//! update of MPI-FAUN, with k×k Grams and tall-skinny panels as the
//! only wire traffic. The kernels are byte-for-byte the single-process
//! ones ([`crate::nmf::products`], [`crate::nmf::halsops`]), so a
//! 1-worker run reproduces `plnmf run --engine fasthals` exactly.
//!
//! Beside the HALS sweep live its multiplicative twin (`mu-sweep`,
//! Frobenius or KL — same shard, [`crate::nmf::mu`]/[`crate::nmf::mukl`]
//! kernels) and the two 2D-grid rounds (`grid-a`/`grid-b`), where the
//! resident shard is one pr×pc *block* of A (both axes local) and the
//! sweep splits into a partial-product round and an H-update round —
//! see the [`super::protocol`] docs for the wire shapes.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail};

use crate::config::{DatasetKind, DatasetProfile};
use crate::data::{DataMatrix, Dataset};
use crate::linalg::Mat;
use crate::nmf::halsops::{update_naive_reg, Shrink, UpdateKind};
use crate::nmf::{mu, mukl, products};
use crate::parallel::ThreadPool;
use crate::serve::wire::{self, ok_obj, BinFrame, BinOp, WirePayload};
use crate::sparse::Csr;
use crate::util::json::Json;
use crate::util::{PhaseTimers, Timer};
use crate::{Elem, Result};

use super::protocol::{self, GramMeta, ShardBegin, ShardLoadMsg};

/// All training jobs resident in this worker process, keyed by the
/// coordinator-chosen job name (the PLNB frame's model-name field).
#[derive(Default)]
pub struct TrainStore {
    jobs: Mutex<HashMap<String, TrainJob>>,
}

#[derive(Default)]
struct TrainJob {
    /// A shard mid-transfer (`begin` seen, `hpanel` not yet).
    pending: Option<PendingShard>,
    /// The finalized shard sweeps run against.
    shard: Option<LoadedShard>,
}

struct PendingShard {
    begin: ShardBegin,
    next_seq: usize,
    got_nnz: usize,
    got_rows: usize,
    triplets: Vec<(usize, usize, Elem)>,
    dense: Vec<Elem>,
}

struct LoadedShard {
    ds: Dataset,
    /// This worker's rows of H (d_s×k).
    h: Mat,
    /// R_s scratch (d_s×k).
    r: Mat,
    /// P_s scratch (V×k).
    p: Mat,
    pool: Arc<ThreadPool>,
    timers: PhaseTimers,
    k: usize,
}

impl TrainStore {
    pub fn new() -> TrainStore {
        TrainStore::default()
    }

    /// Resident shard count (stats/diagnostics).
    pub fn resident(&self) -> usize {
        self.jobs.lock().expect("train store lock").values().filter(|j| j.shard.is_some()).count()
    }
}

impl LoadedShard {
    fn build(begin: ShardBegin, triplets: Vec<(usize, usize, Elem)>, dense: Vec<Elem>, h: Mat) -> LoadedShard {
        let (at, kind) = if begin.sparse {
            (
                DataMatrix::Sparse(Csr::from_triplets(begin.rows, begin.cols, triplets)),
                DatasetKind::SparseText,
            )
        } else {
            (DataMatrix::Dense(Mat::from_vec(begin.rows, begin.cols, dense)), DatasetKind::DenseImage)
        };
        // The shard's "dataset" is the transpose pair every product
        // kernel expects: a is V×d_s, at is the shipped d_s×V rows.
        let a = at.transposed();
        let fro2 = a.fro2();
        let nnz = a.nnz();
        let profile = DatasetProfile {
            name: "shard",
            kind,
            v: a.rows(),
            d: a.cols(),
            nnz,
            zipf_s: 0.0,
            planted_rank: 0,
            paper_stats: None,
        };
        let ds = Dataset { profile, a, at, fro2 };
        let pool = Arc::new(ThreadPool::new(begin.threads));
        let (r, p) = (Mat::zeros(ds.d(), begin.k), Mat::zeros(ds.v(), begin.k));
        LoadedShard { ds, h, r, p, pool, timers: PhaseTimers::new(), k: begin.k }
    }
}

fn ack(kind: &str, extras: Vec<(&str, Json)>) -> WirePayload {
    let mut pairs = vec![("ack", Json::str(kind))];
    pairs.extend(extras);
    WirePayload::Line(ok_obj(pairs).to_string())
}

/// Handle one `0x03 shard-load` frame; the ack is a JSON line.
pub fn op_shard_load(frame: BinFrame, store: &TrainStore) -> Result<WirePayload> {
    let msg = protocol::parse_shard_load(&frame.meta)?;
    let mut jobs = store.jobs.lock().expect("train store lock");
    let job = jobs.entry(frame.model.clone()).or_default();
    match msg {
        ShardLoadMsg::Begin(begin) => {
            if frame.rows * frame.cols != 0 {
                bail!("shard begin carries a {}x{} payload (must be empty)", frame.rows, frame.cols);
            }
            crate::info!(
                "train '{}': begin {}x{} shard (k={}, {}, nnz={}, row0={})",
                frame.model,
                begin.rows,
                begin.cols,
                begin.k,
                if begin.sparse { "sparse" } else { "dense" },
                begin.nnz,
                begin.row0,
            );
            // A new begin abandons any half-shipped predecessor; the
            // resident shard (if any) stays live until the new one
            // finalizes, so a failed re-ship never leaves less state
            // than before it started.
            job.pending = Some(PendingShard {
                begin,
                next_seq: 0,
                got_nnz: 0,
                got_rows: 0,
                triplets: Vec::new(),
                dense: Vec::new(),
            });
            Ok(ack("begin", vec![]))
        }
        ShardLoadMsg::Chunk { seq } => {
            let pending = job
                .pending
                .as_mut()
                .ok_or_else(|| anyhow!("chunk for '{}' without a shard begin", frame.model))?;
            if seq != pending.next_seq {
                bail!("shard chunk out of order: got seq {seq}, expected {}", pending.next_seq);
            }
            pending.next_seq += 1;
            if pending.begin.sparse {
                if frame.cols != 3 {
                    bail!("sparse shard chunk must be nnz x 3, got {}x{}", frame.rows, frame.cols);
                }
                let triplets =
                    protocol::decode_triplets(&frame.data, pending.begin.rows, pending.begin.cols)?;
                pending.got_nnz += triplets.len();
                if pending.got_nnz > pending.begin.nnz {
                    bail!(
                        "shard overflow: {} nnz received, begin declared {}",
                        pending.got_nnz,
                        pending.begin.nnz
                    );
                }
                pending.triplets.extend(triplets);
                Ok(ack("chunk", vec![("nnz", Json::num(pending.got_nnz as f64))]))
            } else {
                if frame.cols != pending.begin.cols {
                    bail!(
                        "dense shard chunk is {}x{}, shard rows are {} wide",
                        frame.rows,
                        frame.cols,
                        pending.begin.cols
                    );
                }
                pending.got_rows += frame.rows;
                if pending.got_rows > pending.begin.rows {
                    bail!(
                        "shard overflow: {} rows received, begin declared {}",
                        pending.got_rows,
                        pending.begin.rows
                    );
                }
                pending.dense.extend_from_slice(&frame.data);
                Ok(ack("chunk", vec![("rows", Json::num(pending.got_rows as f64))]))
            }
        }
        ShardLoadMsg::HPanel { epoch } => {
            if let Some(pending) = job.pending.take() {
                if frame.rows != pending.begin.rows || frame.cols != pending.begin.k {
                    bail!(
                        "hpanel is {}x{}, shard expects {}x{}",
                        frame.rows,
                        frame.cols,
                        pending.begin.rows,
                        pending.begin.k
                    );
                }
                if pending.begin.sparse && pending.got_nnz != pending.begin.nnz {
                    bail!(
                        "shard incomplete at hpanel: {}/{} nnz received",
                        pending.got_nnz,
                        pending.begin.nnz
                    );
                }
                if !pending.begin.sparse && pending.got_rows != pending.begin.rows {
                    bail!(
                        "shard incomplete at hpanel: {}/{} rows received",
                        pending.got_rows,
                        pending.begin.rows
                    );
                }
                let h = Mat::from_vec(frame.rows, frame.cols, frame.data);
                let PendingShard { begin, triplets, dense, .. } = pending;
                job.shard = Some(LoadedShard::build(begin, triplets, dense, h));
                crate::info!("train '{}': shard resident at epoch {epoch}", frame.model);
                Ok(ack("hpanel", vec![("loaded", Json::Bool(true)), ("epoch", Json::num(epoch as f64))]))
            } else if let Some(shard) = job.shard.as_mut() {
                // Factor re-sync on a live shard: the coordinator
                // rewinding every worker to its last checkpoint.
                if frame.rows != shard.ds.d() || frame.cols != shard.k {
                    bail!(
                        "hpanel re-sync is {}x{}, resident shard holds {}x{}",
                        frame.rows,
                        frame.cols,
                        shard.ds.d(),
                        shard.k
                    );
                }
                shard.h = Mat::from_vec(frame.rows, frame.cols, frame.data);
                crate::info!("train '{}': H panel re-synced to epoch {epoch}", frame.model);
                Ok(ack("hpanel", vec![("resync", Json::Bool(true)), ("epoch", Json::num(epoch as f64))]))
            } else {
                bail!("hpanel for '{}' with no pending or resident shard", frame.model)
            }
        }
    }
}

/// Handle one `0x04 sweep` frame: run the local H half-sweep against
/// the broadcast W and reply `Q_s ‖ P_s (‖ H_s)` as a gram-response.
pub fn op_sweep(frame: BinFrame, store: &TrainStore) -> Result<WirePayload> {
    let req = protocol::parse_sweep(&frame.meta)?;
    let mut jobs = store.jobs.lock().expect("train store lock");
    let shard = jobs
        .get_mut(&frame.model)
        .and_then(|j| j.shard.as_mut())
        .ok_or_else(|| anyhow!("{} for train job '{}'", protocol::NO_SHARD, frame.model))?;
    if frame.rows != shard.ds.v() || frame.cols != shard.k {
        bail!(
            "sweep W is {}x{}, shard expects {}x{}",
            frame.rows,
            frame.cols,
            shard.ds.v(),
            shard.k
        );
    }
    let w = Mat::from_vec(frame.rows, frame.cols, frame.data);
    let t = Timer::start();
    let k = shard.k;
    let pool = Arc::clone(&shard.pool);
    let LoadedShard { ds, h, r, p, timers, .. } = shard;
    // The H half-sweep, verbatim from the FAST-HALS engine step —
    // including its elastic-net variant when the sweep meta carries
    // penalties (zero shrink takes the exact unregularized path).
    let shrink = Shrink { l1: req.l1 as Elem, l2: req.l2 as Elem };
    timers.time("spmm_r", || products::at_times(&pool, ds, &w, r));
    let s = timers.time("gram_s", || products::factor_gram(&pool, &w));
    update_naive_reg(&pool, h, &s, r, UpdateKind::Plain, shrink, timers, "h_dmv");
    // The W half-sweep's inputs: local partial product + local Gram.
    timers.time("spmm_p", || products::a_times(&pool, ds, h, p));
    let q = timers.time("gram_q", || products::factor_gram(&pool, h));
    let secs = t.elapsed_secs();

    let rows_h = if req.want_h { h.rows() } else { 0 };
    let mut data = Vec::with_capacity((q.rows() + p.rows() + rows_h) * k);
    data.extend_from_slice(q.data());
    data.extend_from_slice(p.data());
    if req.want_h {
        data.extend_from_slice(h.data());
    }
    let meta = GramMeta { epoch: req.epoch, rows_q: q.rows(), rows_p: p.rows(), rows_h, secs }.to_meta();
    let bytes = wire::encode(BinOp::GramResp, "", &meta, q.rows() + p.rows() + rows_h, k, &data)?;
    Ok(WirePayload::Binary(bytes))
}

/// Handle one `0x06 mu-sweep` frame — the multiplicative twin of
/// [`op_sweep`]. Frobenius replies `Q_s ‖ P_s (‖ H_s)` with the H panel
/// stepped by the MU rule; KL replies `colsum(H_s) ‖ N_s (‖ H_s)` where
/// `N_s` is this shard's V×k partial of the W-update numerator (the
/// coordinator reduces both and applies the W rule).
pub fn op_sweep_mu(frame: BinFrame, store: &TrainStore) -> Result<WirePayload> {
    let req = protocol::parse_sweep_mu(&frame.meta)?;
    let mut jobs = store.jobs.lock().expect("train store lock");
    let shard = jobs
        .get_mut(&frame.model)
        .and_then(|j| j.shard.as_mut())
        .ok_or_else(|| anyhow!("{} for train job '{}'", protocol::NO_SHARD, frame.model))?;
    if frame.rows != shard.ds.v() || frame.cols != shard.k {
        bail!(
            "mu-sweep W is {}x{}, shard expects {}x{}",
            frame.rows,
            frame.cols,
            shard.ds.v(),
            shard.k
        );
    }
    let w = Mat::from_vec(frame.rows, frame.cols, frame.data);
    let t = Timer::start();
    let k = shard.k;
    let pool = Arc::clone(&shard.pool);
    let LoadedShard { ds, h, r, p, timers, .. } = shard;
    let shrink = Shrink { l1: req.l1 as Elem, l2: req.l2 as Elem };

    let (top, rows_q): (Vec<Elem>, usize) = if req.kl {
        // KL: the H half-step is fully local (its denominator is
        // colsum(W), its numerator only touches this shard's documents);
        // the reply carries the shard's two W half-step partials.
        timers.time("h_mukl", || mukl::kl_half_step(&pool, &ds.at, h, &w, r, shrink));
        timers.time("w_numer", || mukl::kl_numer(&pool, &ds.a, &w, h, p));
        let denom = mukl::kl_colsum(&pool, h);
        (denom.iter().map(|&x| x as Elem).collect(), 1)
    } else {
        // Frobenius MU: identical wire shape to the HALS sweep, only
        // the H kernel differs.
        timers.time("spmm_r", || products::at_times(&pool, ds, &w, r));
        let s = timers.time("gram_s", || products::factor_gram(&pool, &w));
        timers.time("h_mu", || mu::mu_update_reg(&pool, h, &s, r, shrink));
        timers.time("spmm_p", || products::a_times(&pool, ds, h, p));
        let q = timers.time("gram_q", || products::factor_gram(&pool, h));
        let rows = q.rows();
        (q.data().to_vec(), rows)
    };
    let secs = t.elapsed_secs();

    let rows_h = if req.want_h { h.rows() } else { 0 };
    let mut data = Vec::with_capacity((rows_q + p.rows() + rows_h) * k);
    data.extend_from_slice(&top);
    data.extend_from_slice(p.data());
    if req.want_h {
        data.extend_from_slice(h.data());
    }
    let meta = GramMeta { epoch: req.epoch, rows_q, rows_p: p.rows(), rows_h, secs }.to_meta();
    let bytes = wire::encode(BinOp::GramResp, "", &meta, rows_q + p.rows() + rows_h, k, &data)?;
    Ok(WirePayload::Binary(bytes))
}

/// Handle one `0x07 grid round-A` frame: the worker's W row panel
/// (v_i×k) comes in, its block's partial product `R_ij = A_ijᵀ·W_i`
/// (d_j×k) goes back (`rows_q = 0`, never an H panel).
pub fn op_grid_a(frame: BinFrame, store: &TrainStore) -> Result<WirePayload> {
    let epoch = protocol::parse_grid_a(&frame.meta)?;
    let mut jobs = store.jobs.lock().expect("train store lock");
    let shard = jobs
        .get_mut(&frame.model)
        .and_then(|j| j.shard.as_mut())
        .ok_or_else(|| anyhow!("{} for train job '{}'", protocol::NO_SHARD, frame.model))?;
    if frame.rows != shard.ds.v() || frame.cols != shard.k {
        bail!(
            "grid-a W panel is {}x{}, block expects {}x{}",
            frame.rows,
            frame.cols,
            shard.ds.v(),
            shard.k
        );
    }
    let w = Mat::from_vec(frame.rows, frame.cols, frame.data);
    let t = Timer::start();
    let k = shard.k;
    let pool = Arc::clone(&shard.pool);
    let LoadedShard { ds, r, timers, .. } = shard;
    timers.time("spmm_r", || products::at_times(&pool, ds, &w, r));
    let secs = t.elapsed_secs();
    let meta = GramMeta { epoch, rows_q: 0, rows_p: r.rows(), rows_h: 0, secs }.to_meta();
    let bytes = wire::encode(BinOp::GramResp, "", &meta, r.rows(), k, r.data())?;
    Ok(WirePayload::Binary(bytes))
}

/// Handle one `0x08 grid round-B` frame: `S = WᵀW` stacked over the
/// column-reduced `R_j` comes in ((k+d_j)×k); the worker updates its
/// H panel (HALS or MU by the meta) and replies
/// `[Q_j] ‖ P_ij (‖ H_j)` — `Q_j` only when `want_q` (one grid row per
/// column answers it; the replicas hold bit-identical panels).
pub fn op_grid_b(frame: BinFrame, store: &TrainStore) -> Result<WirePayload> {
    let req = protocol::parse_grid_b(&frame.meta)?;
    let mut jobs = store.jobs.lock().expect("train store lock");
    let shard = jobs
        .get_mut(&frame.model)
        .and_then(|j| j.shard.as_mut())
        .ok_or_else(|| anyhow!("{} for train job '{}'", protocol::NO_SHARD, frame.model))?;
    let k = shard.k;
    if frame.cols != k || frame.rows != k + shard.ds.d() {
        bail!(
            "grid-b payload is {}x{}, block expects {}x{} (S stacked over R)",
            frame.rows,
            frame.cols,
            k + shard.ds.d(),
            k
        );
    }
    let s = Mat::from_vec(k, k, frame.data[..k * k].to_vec());
    let rred = Mat::from_vec(shard.ds.d(), k, frame.data[k * k..].to_vec());
    let t = Timer::start();
    let pool = Arc::clone(&shard.pool);
    let LoadedShard { ds, h, p, timers, .. } = shard;
    let shrink = Shrink { l1: req.l1 as Elem, l2: req.l2 as Elem };
    if req.mu {
        timers.time("h_mu", || mu::mu_update_reg(&pool, h, &s, &rred, shrink));
    } else {
        update_naive_reg(&pool, h, &s, &rred, UpdateKind::Plain, shrink, timers, "h_dmv");
    }
    timers.time("spmm_p", || products::a_times(&pool, ds, h, p));
    let q = if req.want_q {
        Some(timers.time("gram_q", || products::factor_gram(&pool, h)))
    } else {
        None
    };
    let secs = t.elapsed_secs();

    let rows_q = q.as_ref().map_or(0, Mat::rows);
    let rows_h = if req.want_h { h.rows() } else { 0 };
    let mut data = Vec::with_capacity((rows_q + p.rows() + rows_h) * k);
    if let Some(q) = &q {
        data.extend_from_slice(q.data());
    }
    data.extend_from_slice(p.data());
    if req.want_h {
        data.extend_from_slice(h.data());
    }
    let meta = GramMeta { epoch: req.epoch, rows_q, rows_p: p.rows(), rows_h, secs }.to_meta();
    let bytes = wire::encode(BinOp::GramResp, "", &meta, rows_q + p.rows() + rows_h, k, &data)?;
    Ok(WirePayload::Binary(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;
    use crate::nmf::halsops::update_naive;

    const JOB: &str = "train-0";
    const K: usize = 4;
    const THREADS: usize = 2;

    fn line_json(payload: WirePayload) -> Json {
        match payload {
            WirePayload::Line(s) => Json::parse(s.trim()).unwrap(),
            WirePayload::Binary(_) => panic!("expected a JSON ack line"),
        }
    }

    fn shard_load(store: &TrainStore, meta: &Json, rows: usize, cols: usize, data: &[Elem]) -> Result<Json> {
        let bytes = wire::encode(BinOp::ShardLoad, JOB, meta, rows, cols, data).unwrap();
        op_shard_load(wire::decode(&bytes).unwrap(), store).map(line_json)
    }

    /// Ship the full tiny-sparse dataset as one shard over real frames.
    fn ship_full(store: &TrainStore, ds: &Dataset, h: &Mat) {
        let at = match &ds.at {
            DataMatrix::Sparse(at) => at,
            _ => panic!("tiny-sparse is sparse"),
        };
        let begin = ShardBegin {
            rows: ds.d(),
            cols: ds.v(),
            k: K,
            threads: THREADS,
            sparse: true,
            row0: 0,
            nnz: at.nnz(),
        };
        let resp = shard_load(store, &begin.to_meta(), 0, 0, &[]).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        let mut triplets = Vec::new();
        for row in 0..at.rows() {
            let (cols, vals) = at.row(row);
            for (&c, &x) in cols.iter().zip(vals) {
                triplets.push((row, c as usize, x));
            }
        }
        // Two chunks, to exercise the sequencing path.
        let mid = triplets.len() / 2;
        for (seq, part) in [&triplets[..mid], &triplets[mid..]].iter().enumerate() {
            let data = protocol::encode_triplets(part).unwrap();
            let resp = shard_load(store, &protocol::chunk_meta(seq), part.len(), 3, &data).unwrap();
            assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp}");
        }
        let resp = shard_load(store, &protocol::hpanel_meta(0), h.rows(), h.cols(), h.data()).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp}");
        assert_eq!(resp.get("loaded").as_bool(), Some(true));
    }

    #[test]
    fn sweep_reproduces_the_single_process_half_iteration_exactly() {
        let ds = load_dataset("tiny-sparse", 7).unwrap();
        let f = crate::nmf::Factors::random(ds.v(), ds.d(), K, 7);
        let store = TrainStore::new();
        ship_full(&store, &ds, &f.h);
        assert_eq!(store.resident(), 1);

        let sweep_bytes =
            wire::encode(BinOp::Sweep, JOB, &protocol::sweep_meta(1, true, 0.0, 0.0), f.w.rows(), f.w.cols(), f.w.data())
                .unwrap();
        let reply = op_sweep(wire::decode(&sweep_bytes).unwrap(), &store).unwrap();
        let frame = match reply {
            WirePayload::Binary(b) => wire::decode(&b).unwrap(),
            WirePayload::Line(l) => panic!("sweep failed: {l}"),
        };
        assert_eq!(frame.op, BinOp::GramResp);
        let gm = GramMeta::from_meta(&frame.meta).unwrap();
        assert_eq!((gm.epoch, gm.rows_q, gm.rows_p, gm.rows_h), (1, K, ds.v(), ds.d()));
        assert_eq!(frame.rows, K + ds.v() + ds.d());
        assert_eq!(frame.cols, K);

        // Expected values: the same kernels run directly on a dataset
        // rebuilt exactly as the worker rebuilds it (Aᵀ from triplets,
        // A by transposition) — results must be bitwise identical.
        let at = match &ds.at {
            DataMatrix::Sparse(at) => at.clone(),
            _ => unreachable!(),
        };
        let a = at.transposed();
        let ref_ds = Dataset {
            profile: ds.profile.clone(),
            fro2: a.fro2(),
            a: DataMatrix::Sparse(a),
            at: DataMatrix::Sparse(at),
        };
        let pool = ThreadPool::new(THREADS);
        let mut h = f.h.clone();
        let mut r = Mat::zeros(ref_ds.d(), K);
        let mut p = Mat::zeros(ref_ds.v(), K);
        let mut timers = PhaseTimers::new();
        products::at_times(&pool, &ref_ds, &f.w, &mut r);
        let s = products::factor_gram(&pool, &f.w);
        update_naive(&pool, &mut h, &s, &r, UpdateKind::Plain, &mut timers, "h_dmv");
        products::a_times(&pool, &ref_ds, &h, &mut p);
        let q = products::factor_gram(&pool, &h);

        let qk = K * K;
        let pk = ds.v() * K;
        assert_eq!(&frame.data[..qk], q.data(), "Q_s mismatch");
        assert_eq!(&frame.data[qk..qk + pk], p.data(), "P_s mismatch");
        assert_eq!(&frame.data[qk + pk..], h.data(), "H_s mismatch");

        // want_h = false omits the H panel.
        let sweep_bytes =
            wire::encode(BinOp::Sweep, JOB, &protocol::sweep_meta(2, false, 0.0, 0.0), f.w.rows(), f.w.cols(), f.w.data())
                .unwrap();
        let reply = op_sweep(wire::decode(&sweep_bytes).unwrap(), &store).unwrap();
        let frame = match reply {
            WirePayload::Binary(b) => wire::decode(&b).unwrap(),
            WirePayload::Line(l) => panic!("sweep failed: {l}"),
        };
        assert_eq!(GramMeta::from_meta(&frame.meta).unwrap().rows_h, 0);
        assert_eq!(frame.rows, K + ds.v());
    }

    #[test]
    fn regularized_sweep_matches_the_engines_h_update_exactly() {
        // Penalties in the sweep meta must reach the worker's kernel as
        // the exact Shrink the single-process engine would use — bitwise,
        // like the free sweep above.
        let ds = load_dataset("tiny-sparse", 7).unwrap();
        let f = crate::nmf::Factors::random(ds.v(), ds.d(), K, 7);
        let store = TrainStore::new();
        ship_full(&store, &ds, &f.h);

        let (l1, l2) = (0.05f64, 0.025f64);
        let sweep_bytes = wire::encode(
            BinOp::Sweep,
            JOB,
            &protocol::sweep_meta(1, true, l1, l2),
            f.w.rows(),
            f.w.cols(),
            f.w.data(),
        )
        .unwrap();
        let frame = match op_sweep(wire::decode(&sweep_bytes).unwrap(), &store).unwrap() {
            WirePayload::Binary(b) => wire::decode(&b).unwrap(),
            WirePayload::Line(l) => panic!("sweep failed: {l}"),
        };

        let at = match &ds.at {
            DataMatrix::Sparse(at) => at.clone(),
            _ => unreachable!(),
        };
        let a = at.transposed();
        let ref_ds = Dataset {
            profile: ds.profile.clone(),
            fro2: a.fro2(),
            a: DataMatrix::Sparse(a),
            at: DataMatrix::Sparse(at),
        };
        let pool = ThreadPool::new(THREADS);
        let mut h = f.h.clone();
        let mut free = f.h.clone();
        let mut r = Mat::zeros(ref_ds.d(), K);
        let mut timers = PhaseTimers::new();
        products::at_times(&pool, &ref_ds, &f.w, &mut r);
        let s = products::factor_gram(&pool, &f.w);
        let shrink = Shrink { l1: l1 as Elem, l2: l2 as Elem };
        update_naive_reg(&pool, &mut h, &s, &r, UpdateKind::Plain, shrink, &mut timers, "h_dmv");
        update_naive(&pool, &mut free, &s, &r, UpdateKind::Plain, &mut timers, "h_dmv");

        let qk = K * K;
        let pk = ds.v() * K;
        assert_eq!(&frame.data[qk + pk..], h.data(), "regularized H_s mismatch");
        assert_ne!(h.data(), free.data(), "the penalties did nothing");
    }

    #[test]
    fn hpanel_resync_replaces_the_resident_panel() {
        let ds = load_dataset("tiny-sparse", 7).unwrap();
        let f = crate::nmf::Factors::random(ds.v(), ds.d(), K, 7);
        let store = TrainStore::new();
        ship_full(&store, &ds, &f.h);
        let h2 = Mat::from_fn(ds.d(), K, |i, j| (i + j) as Elem * 0.01 + 0.1);
        let resp = shard_load(&store, &protocol::hpanel_meta(5), h2.rows(), h2.cols(), h2.data()).unwrap();
        assert_eq!(resp.get("resync").as_bool(), Some(true), "{resp}");
        // The next sweep runs from the re-synced panel: its H reply is
        // the update of h2, not of the originally shipped panel.
        let sweep_bytes =
            wire::encode(BinOp::Sweep, JOB, &protocol::sweep_meta(6, true, 0.0, 0.0), f.w.rows(), f.w.cols(), f.w.data())
                .unwrap();
        let frame = match op_sweep(wire::decode(&sweep_bytes).unwrap(), &store).unwrap() {
            WirePayload::Binary(b) => wire::decode(&b).unwrap(),
            WirePayload::Line(l) => panic!("sweep failed: {l}"),
        };
        let pool = ThreadPool::new(THREADS);
        let at = match &ds.at {
            DataMatrix::Sparse(at) => at.clone(),
            _ => unreachable!(),
        };
        let a = at.transposed();
        let ref_ds = Dataset {
            profile: ds.profile.clone(),
            fro2: a.fro2(),
            a: DataMatrix::Sparse(a),
            at: DataMatrix::Sparse(at),
        };
        let mut h = h2.clone();
        let mut r = Mat::zeros(ref_ds.d(), K);
        let mut timers = PhaseTimers::new();
        products::at_times(&pool, &ref_ds, &f.w, &mut r);
        let s = products::factor_gram(&pool, &f.w);
        update_naive(&pool, &mut h, &s, &r, UpdateKind::Plain, &mut timers, "h_dmv");
        let qk = K * K;
        let pk = ds.v() * K;
        assert_eq!(&frame.data[qk + pk..], h.data(), "sweep did not start from the re-synced panel");
    }

    /// The worker rebuilds its dataset as Aᵀ-from-triplets + transpose;
    /// reference computations must run on the identically rebuilt pair
    /// for bitwise comparison.
    fn rebuilt(ds: &Dataset) -> Dataset {
        let at = match &ds.at {
            DataMatrix::Sparse(at) => at.clone(),
            _ => unreachable!(),
        };
        let a = at.transposed();
        Dataset {
            profile: ds.profile.clone(),
            fro2: a.fro2(),
            a: DataMatrix::Sparse(a),
            at: DataMatrix::Sparse(at),
        }
    }

    fn binary_frame(payload: WirePayload) -> BinFrame {
        match payload {
            WirePayload::Binary(b) => wire::decode(&b).unwrap(),
            WirePayload::Line(l) => panic!("op failed: {l}"),
        }
    }

    #[test]
    fn mu_sweep_reproduces_the_single_process_mu_half_iteration_exactly() {
        let ds = load_dataset("tiny-sparse", 7).unwrap();
        let f = crate::nmf::Factors::random(ds.v(), ds.d(), K, 7);
        let store = TrainStore::new();
        ship_full(&store, &ds, &f.h);

        let meta = protocol::sweep_mu_meta(1, true, false, 0.0, 0.0);
        let bytes = wire::encode(BinOp::SweepMu, JOB, &meta, f.w.rows(), f.w.cols(), f.w.data()).unwrap();
        let frame = binary_frame(op_sweep_mu(wire::decode(&bytes).unwrap(), &store).unwrap());
        assert_eq!(frame.op, BinOp::GramResp);
        let gm = GramMeta::from_meta(&frame.meta).unwrap();
        assert_eq!((gm.rows_q, gm.rows_p, gm.rows_h), (K, ds.v(), ds.d()));

        let ref_ds = rebuilt(&ds);
        let pool = ThreadPool::new(THREADS);
        let mut h = f.h.clone();
        let mut r = Mat::zeros(ref_ds.d(), K);
        let mut p = Mat::zeros(ref_ds.v(), K);
        products::at_times(&pool, &ref_ds, &f.w, &mut r);
        let s = products::factor_gram(&pool, &f.w);
        mu::mu_update_reg(&pool, &mut h, &s, &r, Shrink::NONE);
        products::a_times(&pool, &ref_ds, &h, &mut p);
        let q = products::factor_gram(&pool, &h);

        let qk = K * K;
        let pk = ds.v() * K;
        assert_eq!(&frame.data[..qk], q.data(), "Q_s mismatch");
        assert_eq!(&frame.data[qk..qk + pk], p.data(), "P_s mismatch");
        assert_eq!(&frame.data[qk + pk..], h.data(), "MU H_s mismatch");
    }

    #[test]
    fn kl_sweep_ships_the_w_update_partials_exactly() {
        let ds = load_dataset("tiny-sparse", 7).unwrap();
        let f = crate::nmf::Factors::random(ds.v(), ds.d(), K, 7);
        let store = TrainStore::new();
        ship_full(&store, &ds, &f.h);

        let meta = protocol::sweep_mu_meta(1, true, true, 0.0, 0.0);
        let bytes = wire::encode(BinOp::SweepMu, JOB, &meta, f.w.rows(), f.w.cols(), f.w.data()).unwrap();
        let frame = binary_frame(op_sweep_mu(wire::decode(&bytes).unwrap(), &store).unwrap());
        let gm = GramMeta::from_meta(&frame.meta).unwrap();
        // KL replies a 1×k colsum row instead of the k×k Gram.
        assert_eq!((gm.rows_q, gm.rows_p, gm.rows_h), (1, ds.v(), ds.d()));

        let ref_ds = rebuilt(&ds);
        let pool = ThreadPool::new(THREADS);
        let mut h = f.h.clone();
        let mut num = Mat::zeros(ref_ds.d().max(ref_ds.v()), K);
        mukl::kl_half_step(&pool, &ref_ds.at, &mut h, &f.w, &mut num, Shrink::NONE);
        let mut num_w = Mat::zeros(ref_ds.v(), K);
        mukl::kl_numer(&pool, &ref_ds.a, &f.w, &h, &mut num_w);
        let colsum: Vec<Elem> = mukl::kl_colsum(&pool, &h).iter().map(|&x| x as Elem).collect();

        let pk = ds.v() * K;
        assert_eq!(&frame.data[..K], &colsum[..], "colsum(H_s) mismatch");
        assert_eq!(&frame.data[K..K + pk], num_w.data(), "KL numerator partial mismatch");
        assert_eq!(&frame.data[K + pk..], h.data(), "KL H_s mismatch");
    }

    #[test]
    fn grid_rounds_compose_to_the_plain_sweep_bitwise() {
        // One 1×1 "grid" block is the whole dataset; round A + round B
        // must land exactly where the fused sweep lands.
        let ds = load_dataset("tiny-sparse", 7).unwrap();
        let f = crate::nmf::Factors::random(ds.v(), ds.d(), K, 7);
        let store = TrainStore::new();
        ship_full(&store, &ds, &f.h);

        let bytes =
            wire::encode(BinOp::GridSweepA, JOB, &protocol::grid_a_meta(1), f.w.rows(), f.w.cols(), f.w.data())
                .unwrap();
        let ra = binary_frame(op_grid_a(wire::decode(&bytes).unwrap(), &store).unwrap());
        let gm = GramMeta::from_meta(&ra.meta).unwrap();
        assert_eq!((gm.rows_q, gm.rows_p, gm.rows_h), (0, ds.d(), 0));

        let pool = ThreadPool::new(THREADS);
        let s = products::factor_gram(&pool, &f.w);
        let mut stacked = s.data().to_vec();
        stacked.extend_from_slice(&ra.data);
        let breq =
            protocol::GridBReq { epoch: 1, mu: false, want_q: true, want_h: true, l1: 0.0, l2: 0.0 };
        let bytes = wire::encode(
            BinOp::GridSweepB,
            JOB,
            &protocol::grid_b_meta(&breq),
            K + ds.d(),
            K,
            &stacked,
        )
        .unwrap();
        let rb = binary_frame(op_grid_b(wire::decode(&bytes).unwrap(), &store).unwrap());
        let gm = GramMeta::from_meta(&rb.meta).unwrap();
        assert_eq!((gm.rows_q, gm.rows_p, gm.rows_h), (K, ds.v(), ds.d()));

        // Reference: the fused HALS sweep on a fresh shard.
        let store2 = TrainStore::new();
        ship_full(&store2, &ds, &f.h);
        let bytes =
            wire::encode(BinOp::Sweep, JOB, &protocol::sweep_meta(1, true, 0.0, 0.0), f.w.rows(), f.w.cols(), f.w.data())
                .unwrap();
        let fused = binary_frame(op_sweep(wire::decode(&bytes).unwrap(), &store2).unwrap());
        assert_eq!(rb.data, fused.data, "grid rounds diverged from the fused sweep");

        // want_q = false drops the Gram block from the reply.
        let breq = protocol::GridBReq { epoch: 2, mu: false, want_q: false, want_h: false, l1: 0.0, l2: 0.0 };
        let bytes = wire::encode(
            BinOp::GridSweepB,
            JOB,
            &protocol::grid_b_meta(&breq),
            K + ds.d(),
            K,
            &stacked,
        )
        .unwrap();
        let rb = binary_frame(op_grid_b(wire::decode(&bytes).unwrap(), &store).unwrap());
        assert_eq!(GramMeta::from_meta(&rb.meta).unwrap().rows_q, 0);
        assert_eq!(rb.rows, ds.v());
    }

    #[test]
    fn protocol_misuse_is_rejected_loudly() {
        let store = TrainStore::new();
        // Sweep with no shard answers the NO_SHARD marker.
        let bytes = wire::encode(BinOp::Sweep, JOB, &protocol::sweep_meta(0, false, 0.0, 0.0), 2, 2, &[0.0; 4]).unwrap();
        let err = format!("{:#}", op_sweep(wire::decode(&bytes).unwrap(), &store).unwrap_err());
        assert!(err.contains(protocol::NO_SHARD), "{err}");
        // Chunk before begin.
        assert!(shard_load(&store, &protocol::chunk_meta(0), 1, 3, &[0.0, 0.0, 1.0]).is_err());
        // hpanel with nothing pending or resident.
        assert!(shard_load(&store, &protocol::hpanel_meta(0), 1, 1, &[1.0]).is_err());
        // Out-of-order chunk after a begin.
        let begin = ShardBegin { rows: 4, cols: 4, k: 2, threads: 1, sparse: true, row0: 0, nnz: 2 };
        shard_load(&store, &begin.to_meta(), 0, 0, &[]).unwrap();
        assert!(shard_load(&store, &protocol::chunk_meta(1), 1, 3, &[0.0, 0.0, 1.0]).is_err());
        // In-order chunk with an overflow past the declared nnz.
        let data = protocol::encode_triplets(&[(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0)]).unwrap();
        assert!(shard_load(&store, &protocol::chunk_meta(0), 3, 3, &data).is_err());
        // Incomplete shard at hpanel time.
        let h = Mat::zeros(4, 2);
        let err = shard_load(&store, &protocol::hpanel_meta(0), 4, 2, h.data());
        assert!(err.is_err(), "hpanel on an incomplete shard must fail");
    }
}

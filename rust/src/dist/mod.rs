//! Distributed HALS training over the worker fabric (`plnmf train-dist`).
//!
//! Extends the serving fleet's process model to *training*: the dataset
//! is row-sharded across `plnmf serve --train_worker` daemons (documents
//! of Aᵀ, nnz-balanced via [`crate::coordinator::shard`]), each worker
//! keeps its shard and its rows of H resident, and a coordinator drives
//! FAST-HALS epochs by broadcasting W and all-reducing the workers'
//! k×k Grams and V×k partial products — the MPI-FAUN communication
//! pattern carried over the PLNB v2 binary wire protocol
//! ([`crate::serve::wire`]), raw little-endian f32 end to end.
//!
//! * [`protocol`] — frame metas and payload layouts for the three
//!   training ops (`0x03 shard-load`, `0x04 sweep`,
//!   `0x83 gram-response`), including the chunked shard transfer.
//! * [`worker`] — [`TrainStore`]: per-daemon resident shard state and
//!   the op handlers `serve` dispatches binary training frames to.
//! * [`coordinator`] — [`train_dist`]: worker spawn/attach, shard
//!   shipping, the epoch loop with deterministic all-reduce, trace
//!   recording compatible with `plnmf run`, and checkpoint-based
//!   recovery from mid-epoch worker death.

pub mod coordinator;
pub mod protocol;
pub mod worker;

pub use coordinator::{train_dist, DistOpts};
pub use worker::TrainStore;

//! Distributed NMF training over the worker fabric (`plnmf train-dist`).
//!
//! Extends the serving fleet's process model to *training*: the dataset
//! is block-partitioned across `plnmf serve --train_worker` daemons on a
//! pr×pc grid (nnz-balanced on both axes via
//! [`crate::coordinator::shard`]), each worker keeps its A block and its
//! H panel resident, and a coordinator drives epochs by exchanging
//! factor panels and all-reducing k×k Grams and partial products — the
//! MPI-FAUN communication pattern carried over the PLNB v2 binary wire
//! protocol ([`crate::serve::wire`]), raw little-endian f32 end to end.
//! The default 1×N grid is the row-sharded plan (documents of Aᵀ, full
//! W broadcast); `pr > 1` panel-shards W too, shrinking per-epoch
//! coordinator traffic from `O(workers · V·k)` to panel-sized. Both the
//! FAST-HALS and MU engine families run distributed (Frobenius on any
//! grid, KL on 1×N).
//!
//! * [`protocol`] — frame metas and payload layouts for the training
//!   ops (`0x03 shard-load`, `0x04 sweep`, `0x06 mu-sweep`,
//!   `0x07`/`0x08 grid rounds`, `0x83 gram-response`), including the
//!   chunked shard transfer.
//! * [`worker`] — [`TrainStore`]: per-daemon resident shard state and
//!   the op handlers `serve` dispatches binary training frames to.
//! * [`coordinator`] — [`train_dist`]: worker spawn/attach, the
//!   [`GridPlan`] block partition, shard shipping overlapped with the
//!   first epoch, the epoch loop with deterministic all-reduce, trace
//!   recording compatible with `plnmf run`, per-epoch traffic
//!   accounting ([`DistStats`]), and checkpoint-based recovery from
//!   mid-epoch worker death.

pub mod coordinator;
pub mod protocol;
pub mod worker;

pub use coordinator::{train_dist, train_dist_with_stats, DistOpts, DistStats, GridPlan};
pub use worker::TrainStore;

//! Typed messages over the PLNB v2 training ops.
//!
//! The binary frame codec ([`crate::serve::wire`]) gives training three
//! ops — `0x03 shard-load`, `0x04 sweep`, `0x83 gram-response` — whose
//! payloads are raw little-endian f32, never JSON-encoded matrices. This
//! module pins down what rides in each frame's *meta* segment and how
//! structured payloads (CSR triplets, stacked factor panels) are laid
//! out in the f32 data segment, so the coordinator and the worker agree
//! on one schema and both sides validate it.
//!
//! ## Shard-load (`0x03`, coordinator → worker; ack is a JSON line)
//!
//! A shard ships as a `begin` / `chunk`* / `hpanel` sequence, keyed by
//! the frame's model-name field (the per-slot job name, e.g. `train-0`):
//!
//! * `begin` — meta [`ShardBegin`] (shard dims, rank, worker threads,
//!   sparse/dense, global row offset, expected nnz), empty payload.
//! * `chunk` — meta `{kind: "chunk", seq}`; sparse payload is nnz×3
//!   rows of `(local_row, col, value)` (indices carried as exact f32,
//!   see [`MAX_EXACT_INDEX`]), dense payload is row slabs of the Aᵀ
//!   shard. Sequence numbers are strict: a dropped or reordered chunk
//!   is a protocol error, not a silently corrupt shard.
//! * `hpanel` — meta `{kind: "hpanel", epoch}`, payload the d_s×k H
//!   panel. Finalizes a pending shard, or re-syncs the factor panel on
//!   a worker whose shard is already resident (the recovery path).
//!
//! ## Sweep (`0x04`, coordinator → worker)
//!
//! Meta `{epoch, want_h}`, payload the V×k `W` broadcast. The worker
//! answers with a gram-response; errors (most importantly [`NO_SHARD`]
//! from a restarted worker) come back as JSON lines.
//!
//! ## MU sweep (`0x06`, coordinator → worker)
//!
//! The multiplicative twin of `0x04`: meta `{epoch, want_h, kl}` (plus
//! the optional penalties), payload the V×k `W` broadcast. Under
//! Frobenius (`kl` absent/false) the reply stacks `Q_s = HₛᵀHₛ` and
//! `P_s = AₛHₛ` exactly like the HALS sweep; under KL it stacks the 1×k
//! column-sum of `H_s` (the W-update denominator contribution) and the
//! V×k KL numerator partial over the shard's support.
//!
//! ## Grid rounds (`0x07` / `0x08`, coordinator → worker)
//!
//! One pr×pc-grid epoch is two rounds per worker `(i,j)` owning block
//! `A_ij` (rows `v_i` of V × documents `d_j`): round A (`0x07`, meta
//! `{epoch}`) ships the v_i×k row panel `W_i` and the worker answers a
//! gram-response carrying `R_ij = A_ijᵀ·W_i` (d_j×k, as `rows_p`);
//! round B (`0x08`, meta `{epoch, mu, want_q, want_h}` + penalties)
//! ships `S = WᵀW` stacked over the column-reduced `R_j = Σᵢ R_ij`
//! ((k+d_j)×k), the worker updates its replicated H panel (HALS or MU)
//! and answers `Q_j = H_jᵀH_j` (only when `want_q`, the i = 0 grid row)
//! stacked over `P_ij = A_ij·H_j` (v_i×k) and optionally `H_j`.
//!
//! ## Gram-response (`0x83`, worker → coordinator)
//!
//! Meta [`GramMeta`]; payload stacks `rows_q` rows of the Gram-like
//! block, `rows_p` rows of the partial product, and — when the sweep
//! asked `want_h` — the worker's updated H panel (d_s×k), row-wise in
//! that order. Which matrices those blocks hold depends on the op the
//! response answers (see above); the shapes are always validated
//! against the meta on both sides.

use anyhow::{anyhow, bail};

use crate::util::json::Json;
use crate::{Elem, Result};

/// Largest row/column index a sparse triplet may carry. Indices ride
/// the f32 payload, and f32 represents integers exactly only up to
/// 2^24 — a larger index would silently round to a *different row or
/// column*, so both encode and decode refuse it loudly instead.
pub const MAX_EXACT_INDEX: usize = 1 << 24;

/// Max non-zeros per sparse `chunk` frame (3 f32 each → 12 MiB), well
/// under the 64 MiB frame cap even after a whole extra row's spill.
pub const SPARSE_CHUNK_NNZ: usize = 1 << 20;

/// Target payload bytes per dense `chunk` frame.
pub const DENSE_CHUNK_BYTES: usize = 8 * 1024 * 1024;

/// Error-message marker a worker answers a `sweep` with when it holds
/// no resident shard for the job — what a freshly restarted worker
/// says, and what tells the coordinator to re-ship, not retry.
pub const NO_SHARD: &str = "no resident shard";

/// Rows of the Aᵀ shard per dense `chunk` frame.
pub fn dense_chunk_rows(cols: usize) -> usize {
    (DENSE_CHUNK_BYTES / 4 / cols.max(1)).max(1)
}

fn req_usize(meta: &Json, key: &str) -> Result<usize> {
    meta.get(key)
        .as_usize()
        .ok_or_else(|| anyhow!("training meta needs a non-negative integer \"{key}\", got {}", meta.get(key)))
}

// ---------------------------------------------------------------------------
// Shard-load.
// ---------------------------------------------------------------------------

/// The `begin` announcement of a shard-load sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardBegin {
    /// Shard rows of Aᵀ = documents owned by this worker (d_s).
    pub rows: usize,
    /// Shard columns of Aᵀ = the full vocabulary (V).
    pub cols: usize,
    /// Factor rank k.
    pub k: usize,
    /// Thread-pool size the worker must solve with — shipped so a
    /// 1-worker run reproduces the single-process reduction orders
    /// bit-for-bit.
    pub threads: usize,
    /// Sparse (CSR triplets) vs dense (row slabs) chunk payloads.
    pub sparse: bool,
    /// Global row offset of this shard in H (for logs/diagnostics).
    pub row0: usize,
    /// Expected nnz across all sparse chunks (0 for dense).
    pub nnz: usize,
}

impl ShardBegin {
    pub fn to_meta(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("begin")),
            ("rows", Json::num(self.rows as f64)),
            ("cols", Json::num(self.cols as f64)),
            ("k", Json::num(self.k as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("sparse", Json::Bool(self.sparse)),
            ("row0", Json::num(self.row0 as f64)),
            ("nnz", Json::num(self.nnz as f64)),
        ])
    }

    pub fn from_meta(meta: &Json) -> Result<ShardBegin> {
        let begin = ShardBegin {
            rows: req_usize(meta, "rows")?,
            cols: req_usize(meta, "cols")?,
            k: req_usize(meta, "k")?,
            threads: req_usize(meta, "threads")?,
            sparse: meta
                .get("sparse")
                .as_bool()
                .ok_or_else(|| anyhow!("shard begin needs a boolean \"sparse\""))?,
            row0: req_usize(meta, "row0")?,
            nnz: req_usize(meta, "nnz")?,
        };
        if begin.rows == 0 || begin.cols == 0 || begin.k == 0 || begin.threads == 0 {
            bail!(
                "degenerate shard begin: rows={} cols={} k={} threads={}",
                begin.rows,
                begin.cols,
                begin.k,
                begin.threads
            );
        }
        Ok(begin)
    }
}

/// A parsed shard-load frame meta.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardLoadMsg {
    Begin(ShardBegin),
    Chunk { seq: usize },
    HPanel { epoch: usize },
}

pub fn chunk_meta(seq: usize) -> Json {
    Json::obj(vec![("kind", Json::str("chunk")), ("seq", Json::num(seq as f64))])
}

pub fn hpanel_meta(epoch: usize) -> Json {
    Json::obj(vec![("kind", Json::str("hpanel")), ("epoch", Json::num(epoch as f64))])
}

pub fn parse_shard_load(meta: &Json) -> Result<ShardLoadMsg> {
    match meta.get("kind").as_str() {
        Some("begin") => Ok(ShardLoadMsg::Begin(ShardBegin::from_meta(meta)?)),
        Some("chunk") => Ok(ShardLoadMsg::Chunk { seq: req_usize(meta, "seq")? }),
        Some("hpanel") => Ok(ShardLoadMsg::HPanel { epoch: req_usize(meta, "epoch")? }),
        other => bail!(
            "shard-load meta needs \"kind\": begin|chunk|hpanel, got {}",
            other.unwrap_or("(absent)")
        ),
    }
}

// ---------------------------------------------------------------------------
// Sweep.
// ---------------------------------------------------------------------------

/// A parsed sweep request meta.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepReq {
    pub epoch: usize,
    /// Whether the reply must append the worker's updated H panel (the
    /// coordinator's checkpoint epochs).
    pub want_h: bool,
    /// Elastic-net penalties on the worker's H half-sweep. Zero values
    /// stay off the wire, so an unregularized coordinator emits exactly
    /// the pre-spec meta and an old worker would parse it unchanged.
    pub l1: f64,
    pub l2: f64,
}

pub fn sweep_meta(epoch: usize, want_h: bool, l1: f64, l2: f64) -> Json {
    let mut pairs = vec![
        ("epoch", Json::num(epoch as f64)),
        ("want_h", Json::Bool(want_h)),
    ];
    if l1 != 0.0 {
        pairs.push(("l1", Json::num(l1)));
    }
    if l2 != 0.0 {
        pairs.push(("l2", Json::num(l2)));
    }
    Json::obj(pairs)
}

pub fn parse_sweep(meta: &Json) -> Result<SweepReq> {
    // Absent ⇒ unregularized; present-but-bogus (negative, NaN,
    // non-number) is a protocol error, never silently 0.
    let reg = |key: &str| -> Result<f64> {
        match meta.get(key) {
            Json::Null => Ok(0.0),
            v => match v.as_f64() {
                Some(x) if x.is_finite() && x >= 0.0 => Ok(x),
                _ => bail!("sweep meta \"{key}\" must be a finite number >= 0, got {v}"),
            },
        }
    };
    Ok(SweepReq {
        epoch: req_usize(meta, "epoch")?,
        want_h: meta
            .get("want_h")
            .as_bool()
            .ok_or_else(|| anyhow!("sweep meta needs a boolean \"want_h\""))?,
        l1: reg("l1")?,
        l2: reg("l2")?,
    })
}

// ---------------------------------------------------------------------------
// MU sweep.
// ---------------------------------------------------------------------------

/// A parsed MU-sweep (`0x06`) request meta: the [`SweepReq`] fields plus
/// the loss selector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MuSweepReq {
    pub epoch: usize,
    pub want_h: bool,
    /// KL divergence instead of Frobenius (changes both the worker's H
    /// half-step and the layout of its reply — see the module docs).
    pub kl: bool,
    pub l1: f64,
    pub l2: f64,
}

pub fn sweep_mu_meta(epoch: usize, want_h: bool, kl: bool, l1: f64, l2: f64) -> Json {
    let mut meta = sweep_meta(epoch, want_h, l1, l2);
    if kl {
        if let Json::Obj(pairs) = &mut meta {
            pairs.insert("kl".to_string(), Json::Bool(true));
        }
    }
    meta
}

pub fn parse_sweep_mu(meta: &Json) -> Result<MuSweepReq> {
    let base = parse_sweep(meta)?;
    let kl = match meta.get("kl") {
        Json::Null => false,
        v => v.as_bool().ok_or_else(|| anyhow!("mu-sweep meta \"kl\" must be a boolean, got {v}"))?,
    };
    Ok(MuSweepReq { epoch: base.epoch, want_h: base.want_h, kl, l1: base.l1, l2: base.l2 })
}

// ---------------------------------------------------------------------------
// Grid rounds.
// ---------------------------------------------------------------------------

pub fn grid_a_meta(epoch: usize) -> Json {
    Json::obj(vec![("epoch", Json::num(epoch as f64))])
}

pub fn parse_grid_a(meta: &Json) -> Result<usize> {
    req_usize(meta, "epoch")
}

/// A parsed grid round-B (`0x08`) request meta.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridBReq {
    pub epoch: usize,
    /// Multiplicative H update instead of the HALS half-sweep.
    pub mu: bool,
    /// Whether the reply must lead with `Q_j = H_jᵀH_j` (asked of one
    /// grid row only — the replicas would all answer the same bits).
    pub want_q: bool,
    /// Whether the reply must append the updated H panel.
    pub want_h: bool,
    pub l1: f64,
    pub l2: f64,
}

pub fn grid_b_meta(req: &GridBReq) -> Json {
    let mut meta = sweep_meta(req.epoch, req.want_h, req.l1, req.l2);
    if let Json::Obj(pairs) = &mut meta {
        if req.mu {
            pairs.insert("mu".to_string(), Json::Bool(true));
        }
        pairs.insert("want_q".to_string(), Json::Bool(req.want_q));
    }
    meta
}

pub fn parse_grid_b(meta: &Json) -> Result<GridBReq> {
    let base = parse_sweep(meta)?;
    let mu = match meta.get("mu") {
        Json::Null => false,
        v => v.as_bool().ok_or_else(|| anyhow!("grid-b meta \"mu\" must be a boolean, got {v}"))?,
    };
    let want_q = meta
        .get("want_q")
        .as_bool()
        .ok_or_else(|| anyhow!("grid-b meta needs a boolean \"want_q\""))?;
    Ok(GridBReq { epoch: base.epoch, mu, want_q, want_h: base.want_h, l1: base.l1, l2: base.l2 })
}

// ---------------------------------------------------------------------------
// Gram-response.
// ---------------------------------------------------------------------------

/// Meta of a gram-response frame; the payload stacks `rows_q + rows_p +
/// rows_h` rows of width k: `Q_s` then `P_s` then (optionally) `H_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GramMeta {
    pub epoch: usize,
    pub rows_q: usize,
    pub rows_p: usize,
    /// 0 when the sweep did not ask for the H panel.
    pub rows_h: usize,
    /// Worker-side wall time of the half-sweep (diagnostics).
    pub secs: f64,
}

impl GramMeta {
    pub fn to_meta(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::num(self.epoch as f64)),
            ("rows_q", Json::num(self.rows_q as f64)),
            ("rows_p", Json::num(self.rows_p as f64)),
            ("rows_h", Json::num(self.rows_h as f64)),
            ("secs", Json::num(self.secs)),
        ])
    }

    pub fn from_meta(meta: &Json) -> Result<GramMeta> {
        Ok(GramMeta {
            epoch: req_usize(meta, "epoch")?,
            rows_q: req_usize(meta, "rows_q")?,
            rows_p: req_usize(meta, "rows_p")?,
            rows_h: req_usize(meta, "rows_h")?,
            secs: meta.get("secs").as_f64().unwrap_or(0.0),
        })
    }
}

// ---------------------------------------------------------------------------
// Sparse triplet payloads.
// ---------------------------------------------------------------------------

/// Encode `(local_row, col, value)` triplets as nnz×3 payload rows,
/// refusing any index outside the exact-f32 range.
pub fn encode_triplets(triplets: &[(usize, usize, Elem)]) -> Result<Vec<Elem>> {
    let mut out = Vec::with_capacity(triplets.len() * 3);
    for &(r, c, v) in triplets {
        if r >= MAX_EXACT_INDEX || c >= MAX_EXACT_INDEX {
            bail!(
                "sparse shard index ({r},{c}) exceeds the exact-f32 payload range \
                 ({MAX_EXACT_INDEX}); it would silently land in a different row/column"
            );
        }
        out.push(r as Elem);
        out.push(c as Elem);
        out.push(v);
    }
    Ok(out)
}

/// Decode an nnz×3 chunk payload back into triplets, validating every
/// index round-trips exactly and lands inside the `rows`×`cols` shard.
pub fn decode_triplets(data: &[Elem], rows: usize, cols: usize) -> Result<Vec<(usize, usize, Elem)>> {
    if data.len() % 3 != 0 {
        bail!("sparse chunk payload has {} values (not a multiple of 3)", data.len());
    }
    let mut out = Vec::with_capacity(data.len() / 3);
    for (i, t) in data.chunks_exact(3).enumerate() {
        let (r, c, v) = (t[0], t[1], t[2]);
        let (ri, ci) = (r as usize, c as usize);
        if !(r.is_finite() && c.is_finite()) || r.fract() != 0.0 || c.fract() != 0.0 || r < 0.0 || c < 0.0 {
            bail!("sparse chunk triplet {i} has non-integer indices ({r}, {c})");
        }
        if ri >= rows || ci >= cols {
            bail!("sparse chunk triplet {i} at ({ri},{ci}) outside the {rows}x{cols} shard");
        }
        if !v.is_finite() {
            bail!("sparse chunk triplet {i} value {v} is not finite");
        }
        out.push((ri, ci, v));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_begin_roundtrips_and_validates() {
        let b = ShardBegin { rows: 40, cols: 80, k: 4, threads: 2, sparse: true, row0: 10, nnz: 200 };
        let parsed = match parse_shard_load(&b.to_meta()).unwrap() {
            ShardLoadMsg::Begin(p) => p,
            other => panic!("expected begin, got {other:?}"),
        };
        assert_eq!(parsed, b);
        // Degenerate dims are loud errors, not zero-sized pools/panels.
        for broken in ["rows", "cols", "k", "threads"] {
            let mut meta = b.to_meta();
            if let Json::Obj(pairs) = &mut meta {
                pairs.insert(broken.to_string(), Json::num(0.0));
            }
            assert!(ShardBegin::from_meta(&meta).is_err(), "{broken}=0 accepted");
        }
    }

    #[test]
    fn chunk_and_hpanel_metas_parse() {
        assert_eq!(parse_shard_load(&chunk_meta(3)).unwrap(), ShardLoadMsg::Chunk { seq: 3 });
        assert_eq!(
            parse_shard_load(&hpanel_meta(7)).unwrap(),
            ShardLoadMsg::HPanel { epoch: 7 }
        );
        assert!(parse_shard_load(&Json::obj(vec![("kind", Json::str("nope"))])).is_err());
        assert!(parse_shard_load(&Json::Null).is_err());
    }

    #[test]
    fn sweep_and_gram_metas_roundtrip() {
        let req = parse_sweep(&sweep_meta(5, true, 0.0, 0.0)).unwrap();
        assert_eq!(req, SweepReq { epoch: 5, want_h: true, l1: 0.0, l2: 0.0 });
        assert!(parse_sweep(&Json::obj(vec![("epoch", Json::num(1.0))])).is_err());

        let gm = GramMeta { epoch: 2, rows_q: 4, rows_p: 80, rows_h: 20, secs: 0.25 };
        let re = GramMeta::from_meta(&gm.to_meta()).unwrap();
        assert_eq!(re, gm);
    }

    #[test]
    fn sweep_regularization_is_absent_when_zero_and_strict_when_present() {
        // Unregularized metas are byte-compatible with the pre-spec wire.
        let meta = sweep_meta(3, false, 0.0, 0.0).to_string();
        assert!(!meta.contains("l1") && !meta.contains("l2"), "{meta}");
        // Non-zero penalties round-trip.
        let req = parse_sweep(&sweep_meta(3, false, 0.05, 0.025)).unwrap();
        assert_eq!((req.l1, req.l2), (0.05, 0.025));
        // Bogus values are protocol errors, not silently 0.
        for bad in [r#"{"epoch": 1, "want_h": false, "l1": -0.5}"#,
                    r#"{"epoch": 1, "want_h": false, "l2": "big"}"#] {
            let j = Json::parse(bad).unwrap();
            assert!(parse_sweep(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn mu_sweep_meta_roundtrips_and_defaults_to_frobenius() {
        // Absent "kl" is Frobenius; the Frobenius meta is byte-identical
        // to the HALS sweep meta (one parser family on the worker).
        let fro = sweep_mu_meta(4, true, false, 0.0, 0.0);
        assert_eq!(fro.to_string(), sweep_meta(4, true, 0.0, 0.0).to_string());
        let req = parse_sweep_mu(&fro).unwrap();
        assert_eq!(req, MuSweepReq { epoch: 4, want_h: true, kl: false, l1: 0.0, l2: 0.0 });
        let kl = parse_sweep_mu(&sweep_mu_meta(9, false, true, 0.1, 0.05)).unwrap();
        assert!(kl.kl);
        assert_eq!((kl.l1, kl.l2), (0.1, 0.05));
        // Bogus kl is a protocol error, not silently Frobenius.
        let bad = Json::parse(r#"{"epoch": 1, "want_h": false, "kl": "yes"}"#).unwrap();
        assert!(parse_sweep_mu(&bad).is_err());
    }

    #[test]
    fn grid_round_metas_roundtrip() {
        assert_eq!(parse_grid_a(&grid_a_meta(6)).unwrap(), 6);
        assert!(parse_grid_a(&Json::Null).is_err());

        let req = GridBReq { epoch: 3, mu: true, want_q: true, want_h: false, l1: 0.2, l2: 0.0 };
        assert_eq!(parse_grid_b(&grid_b_meta(&req)).unwrap(), req);
        let hals = GridBReq { epoch: 1, mu: false, want_q: false, want_h: true, l1: 0.0, l2: 0.0 };
        assert_eq!(parse_grid_b(&grid_b_meta(&hals)).unwrap(), hals);
        // want_q is mandatory: a worker must never guess whether to pay
        // for (and stack) the k×k Gram.
        let bad = Json::parse(r#"{"epoch": 1, "want_h": false}"#).unwrap();
        assert!(parse_grid_b(&bad).is_err());
    }

    #[test]
    fn triplets_roundtrip_exactly() {
        let triplets = vec![(0usize, 5usize, 1.5 as Elem), (3, 0, -2.25), (7, 79, 0.125)];
        let data = encode_triplets(&triplets).unwrap();
        assert_eq!(data.len(), 9);
        let back = decode_triplets(&data, 8, 80).unwrap();
        assert_eq!(back, triplets);
    }

    #[test]
    fn triplet_guards_reject_inexact_and_out_of_range() {
        // Encoding an index past 2^24 must fail rather than round.
        assert!(encode_triplets(&[(MAX_EXACT_INDEX, 0, 1.0)]).is_err());
        assert!(encode_triplets(&[(0, MAX_EXACT_INDEX, 1.0)]).is_err());
        // The largest exact index is fine.
        assert!(encode_triplets(&[(MAX_EXACT_INDEX - 1, 0, 1.0)]).is_ok());
        // Decode rejects fractional indices, out-of-shard indices,
        // non-finite values, and ragged payloads.
        assert!(decode_triplets(&[0.5, 0.0, 1.0], 4, 4).is_err());
        assert!(decode_triplets(&[0.0, 9.0, 1.0], 4, 4).is_err());
        assert!(decode_triplets(&[9.0, 0.0, 1.0], 4, 4).is_err());
        assert!(decode_triplets(&[0.0, 0.0, Elem::NAN], 4, 4).is_err());
        assert!(decode_triplets(&[0.0, 0.0], 4, 4).is_err());
        assert!(decode_triplets(&[-1.0, 0.0, 1.0], 4, 4).is_err());
    }

    #[test]
    fn dense_chunk_rows_is_positive_and_bounded() {
        assert_eq!(dense_chunk_rows(0), DENSE_CHUNK_BYTES / 4);
        assert!(dense_chunk_rows(1_000_000_000) >= 1);
        let rows = dense_chunk_rows(512);
        assert!(rows * 512 * 4 <= DENSE_CHUNK_BYTES);
    }
}

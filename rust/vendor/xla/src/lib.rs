//! Offline stub of the `xla` (PJRT) crate.
//!
//! The accelerated engines (`plnmf-accel`, `mu-accel`) execute AOT-lowered
//! HLO through PJRT via the real `xla` crate, which needs a prebuilt
//! libxla that is unavailable in the offline build container. This stub
//! presents the same type/method surface so the coordinator compiles
//! unchanged; every runtime entry point returns an [`Error`] explaining
//! the situation. Engine construction therefore fails cleanly and the
//! comparison runner reports the XLA engines as *skipped* — the same
//! degradation path as running without `make artifacts`.
//!
//! To enable the accelerated path, replace this path dependency with the
//! real `xla` crate in `rust/Cargo.toml`.

use std::fmt;

/// Error type mirroring `xla::Error` (not `Send`/`Sync`-constrained by
/// callers; plnmf maps it through `anyhow!` immediately).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built with the offline xla stub; \
         swap rust/vendor/xla for the real xla crate to enable accelerated engines)"
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on device buffers; real signature returns per-device,
    /// per-output buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Host literal (tensor value).
pub struct Literal;

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn shape(&self) -> Result<Shape, Error> {
        Err(unavailable("Literal::shape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Tensor/tuple shape.
pub enum Shape {
    Array(Vec<usize>),
    Tuple(Vec<Shape>),
}

/// Parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_entry_points_fail_with_clear_message() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("offline xla stub"));
        let e = HloModuleProto::from_text_file("x.hlo.txt").err().unwrap();
        assert!(e.to_string().contains("PJRT runtime unavailable"));
    }
}

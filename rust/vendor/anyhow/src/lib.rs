//! Offline stand-in for the `anyhow` crate.
//!
//! The container this repository builds in has no crates.io access, so the
//! subset of `anyhow` the workspace uses is reimplemented here behind the
//! same names: [`Error`], [`Result`], the [`Context`] extension trait, and
//! the `anyhow!` / `bail!` / `ensure!` macros. Error values carry a chain
//! of messages (outermost context first); `{}` prints the outermost
//! message, `{:#}` the full chain joined with `": "`, and `{:?}` the
//! multi-line `Caused by:` form — matching how the real crate renders in
//! each position.

use std::fmt::{self, Debug, Display};

/// A dynamically-typed error: a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create from a single printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Disjoint from the impl above because `Error` deliberately does not
// implement `std::error::Error` (the same coherence carve-out the real
// anyhow relies on for its blanket `From`).
impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_forms() {
        let e: Error = Error::from(io_err()).context("reading x");
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.chain().count(), 2);
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("absent").unwrap_err()), "absent");
        let nested: Result<()> = Err(Error::msg("inner"));
        let e = nested.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: inner");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "three");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}

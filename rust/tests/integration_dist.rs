//! End-to-end distributed training over real `plnmf serve
//! --train_worker` *processes* (the in-process attach-mode parity tests
//! live in `plnmf::dist::coordinator`; this file is about process
//! lifecycle and fault recovery, which need a real binary to spawn and
//! a real PID to kill).
//!
//! The headline assertions:
//!
//! * **Spawned parity** — `train_dist` spawning its own worker
//!   processes produces the same trace (within the paper's float
//!   tolerance) as the single-process FAST-HALS driver.
//! * **Fault recovery** — chaos-killing one of two workers mid-run
//!   makes the coordinator respawn it, re-ship its shard, rewind to the
//!   last consistent checkpoint, and still finish the full epoch
//!   budget with a final error matching an undisturbed distributed run.
//! * **2D grid** — the same two claims again on a 2×2 worker grid
//!   (panel-sharded W *and* H), plus the MU engine over a real process.

use std::path::PathBuf;

use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::Driver;
use plnmf::dist::{train_dist, DistOpts};

/// The `plnmf` binary workers are spawned from (built by cargo for us).
const PLNMF_BIN: &str = env!("CARGO_BIN_EXE_plnmf");

/// Distributed ≡ single-process tolerance from the issue's acceptance
/// bar: the all-reduce reorders f32 sums, nothing else differs.
const TOL: f64 = 2e-3;

fn dist_cfg(dataset: &str, iters: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.dataset = dataset.into();
    cfg.engine = EngineKind::FastHals;
    cfg.k = 4;
    cfg.max_iters = iters;
    cfg.record_every = 1;
    cfg.threads = 2;
    cfg.seed = 11;
    cfg
}

fn spawn_opts(workers: usize, sync_every: usize) -> DistOpts {
    DistOpts {
        binary: Some(PathBuf::from(PLNMF_BIN)),
        workers,
        sync_every,
        ..DistOpts::default()
    }
}

fn grid_opts(pr: usize, pc: usize, sync_every: usize) -> DistOpts {
    DistOpts { grid: Some((pr, pc)), ..spawn_opts(pr * pc, sync_every) }
}

fn assert_traces_close(dist: &plnmf::coordinator::RunReport, single: &plnmf::coordinator::RunReport) {
    assert_eq!(dist.trace.len(), single.trace.len(), "trace lengths diverge");
    for (d, s) in dist.trace.iter().zip(&single.trace) {
        assert_eq!(d.iter, s.iter);
        assert!(
            (d.rel_error - s.rel_error).abs() <= TOL,
            "iter {}: dist {} vs single {}",
            d.iter,
            d.rel_error,
            s.rel_error
        );
    }
}

#[test]
fn spawned_workers_match_the_single_process_trace() {
    let cfg = dist_cfg("tiny-sparse", 8);
    let dist = train_dist(&cfg, &spawn_opts(2, 3)).unwrap();
    let single = Driver::from_config(&cfg).unwrap().run().unwrap();

    assert_eq!(dist.engine, "fasthals-dist");
    assert_eq!(dist.trace.len(), single.trace.len(), "trace lengths diverge");
    for (d, s) in dist.trace.iter().zip(&single.trace) {
        assert_eq!(d.iter, s.iter);
        assert!(
            (d.rel_error - s.rel_error).abs() <= TOL,
            "iter {}: dist {} vs single {}",
            d.iter,
            d.rel_error,
            s.rel_error
        );
    }
}

#[test]
fn killing_a_worker_mid_run_recovers_and_completes() {
    // Two spawned workers; worker 1 is chaos-killed at the start of
    // epoch 5, between checkpoints (sync_every=3 → last checkpoint at
    // epoch 3). The coordinator must respawn it on a fresh port,
    // re-ship its shard and checkpointed H panel, rewind W, and finish
    // all 10 epochs.
    let cfg = dist_cfg("tiny-sparse", 10);
    let mut opts = spawn_opts(2, 3);
    opts.chaos_kill = Some((5, 1));
    let killed = train_dist(&cfg, &opts).unwrap();

    let undisturbed = train_dist(&cfg, &spawn_opts(2, 3)).unwrap();

    // The full epoch budget ran despite the mid-run death…
    assert_eq!(
        killed.trace.last().map(|r| r.iter),
        Some(cfg.max_iters),
        "recovered run must reach the final epoch"
    );
    assert_eq!(killed.trace.len(), undisturbed.trace.len());
    // …and rewound epochs were recomputed from consistent state, so the
    // whole trace matches an undisturbed distributed run.
    for (k, u) in killed.trace.iter().zip(&undisturbed.trace) {
        assert_eq!(k.iter, u.iter);
        assert!(
            (k.rel_error - u.rel_error).abs() <= TOL,
            "iter {}: killed-run {} vs undisturbed {}",
            k.iter,
            k.rel_error,
            u.rel_error
        );
    }
    assert!(killed.final_rel_error.is_finite());
}

#[test]
fn a_2x2_grid_of_spawned_workers_matches_the_single_process_trace() {
    // Four real worker processes on a 2×2 grid: W is panel-sharded
    // across grid rows and H across grid columns, epochs run as two
    // wire rounds, and the trace must still match the single-process
    // FAST-HALS driver within the same tolerance as the 1D plan.
    let cfg = dist_cfg("tiny-sparse", 8);
    let dist = train_dist(&cfg, &grid_opts(2, 2, 3)).unwrap();
    let single = Driver::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(dist.engine, "fasthals-dist");
    assert_traces_close(&dist, &single);
}

#[test]
fn killing_a_grid_worker_mid_run_recovers_and_completes() {
    // Chaos-kill block (1,0) of a 2×2 grid at the start of epoch 5
    // (sync_every=3 → rewind to the epoch-3 checkpoint). Recovery must
    // respawn the dead process, re-ship its A block, resync the H
    // panels of the survivors, and finish all 10 epochs on a trace
    // matching an undisturbed grid run.
    let cfg = dist_cfg("tiny-sparse", 10);
    let mut opts = grid_opts(2, 2, 3);
    opts.chaos_kill = Some((5, 2));
    let killed = train_dist(&cfg, &opts).unwrap();

    let undisturbed = train_dist(&cfg, &grid_opts(2, 2, 3)).unwrap();

    assert_eq!(
        killed.trace.last().map(|r| r.iter),
        Some(cfg.max_iters),
        "recovered grid run must reach the final epoch"
    );
    assert_traces_close(&killed, &undisturbed);
    assert!(killed.final_rel_error.is_finite());
}

#[test]
fn the_mu_engine_runs_distributed_with_single_process_parity() {
    // One spawned worker runs the exact single-process multiplicative
    // update math plus a wire hop — the engine-family acceptance bar.
    let mut cfg = dist_cfg("tiny-sparse", 8);
    cfg.engine = EngineKind::Mu;
    let dist = train_dist(&cfg, &spawn_opts(1, 3)).unwrap();
    let single = Driver::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(dist.engine, "mu-dist");
    assert_traces_close(&dist, &single);
}

#[test]
fn dense_datasets_shard_and_train_too() {
    // The dense wire path (row-slab chunks instead of triplets) over a
    // real process, on the dense unit-test profile.
    let cfg = dist_cfg("tiny", 6);
    let dist = train_dist(&cfg, &spawn_opts(2, 2)).unwrap();
    let single = Driver::from_config(&cfg).unwrap().run().unwrap();
    assert!(
        (dist.final_rel_error - single.final_rel_error).abs() <= TOL,
        "dense dist {} vs single {}",
        dist.final_rel_error,
        single.final_rel_error
    );
}

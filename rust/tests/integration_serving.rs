//! End-to-end serving: train → save → load → project → recommend, both
//! through the library API and through the exact CLI code path
//! (`plnmf run --model … && plnmf transform --model …`).

use std::path::PathBuf;
use std::sync::Arc;

use plnmf::bench::cli_main;
use plnmf::cli::Args;
use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::Driver;
use plnmf::data::{load_dataset, DataMatrix};
use plnmf::linalg::Mat;
use plnmf::parallel::ThreadPool;
use plnmf::serve::{load_model, save_model, ModelMeta, Projector, ProjectorOpts, Queries};

fn tmpdir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("plnmf-serve-it-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn cli(line: &str) -> anyhow::Result<()> {
    cli_main(Args::parse(line.split_whitespace().map(|s| s.to_string())).unwrap())
}

#[test]
fn trained_model_projects_training_docs_accurately() {
    // Train on tiny-sparse, then project the training columns: the
    // recovered mixtures must reconstruct about as well as the trained H
    // does (the projection solves the same per-column subproblem the H
    // update solves at convergence).
    let mut cfg = RunConfig::default();
    cfg.dataset = "tiny-sparse".into();
    cfg.engine = EngineKind::PlNmf;
    cfg.k = 6;
    cfg.max_iters = 30;
    cfg.threads = 2;
    let mut driver = Driver::from_config(&cfg).unwrap();
    let report = driver.run().unwrap();
    let factors = driver.engine_mut().factors().clone();

    let pool = Arc::new(ThreadPool::new(2));
    let opts = ProjectorOpts { sweeps: 100, micro_batch: 16, ..Default::default() };
    let projector = Projector::new(factors.w.clone(), pool, opts).unwrap();
    let queries = match &driver.ds.at {
        DataMatrix::Sparse(c) => Queries::Sparse(c),
        DataMatrix::Dense(m) => Queries::Dense(m),
    };
    let h = projector.project(queries).unwrap();
    let res = projector.residuals(queries, &h).unwrap();
    let mean = res.iter().sum::<f64>() / res.len() as f64;
    // The global relative error bounds the average per-column fit the
    // training reached; fresh per-column solves can only do better
    // column-wise, so the mean per-doc residual must be in the same
    // regime (allow slack for the EPS floor and the A columns' spread).
    assert!(
        mean < report.final_rel_error.max(0.05) * 3.0,
        "mean projection residual {mean} vs training error {}",
        report.final_rel_error
    );
}

#[test]
fn model_file_roundtrips_factors_exactly() {
    let dir = tmpdir("roundtrip");
    let mut cfg = RunConfig::default();
    cfg.dataset = "tiny".into();
    cfg.k = 4;
    cfg.max_iters = 3;
    cfg.threads = 1;
    let mut driver = Driver::from_config(&cfg).unwrap();
    driver.run().unwrap();
    let factors = driver.engine_mut().factors().clone();
    let path = dir.join("model.json");
    let meta = ModelMeta { engine: "plnmf-cpu".into(), ..Default::default() };
    save_model(&path, &factors, &meta).unwrap();
    let (re, _) = load_model(&path).unwrap();
    assert_eq!(re.w, factors.w, "W must round-trip bit-exactly");
    assert_eq!(re.h, factors.h, "H must round-trip bit-exactly");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_train_save_transform_recommend_roundtrip() {
    let dir = tmpdir("cli");
    let model = dir.join("model.json");
    let hcsv = dir.join("h.csv");
    let rcsv = dir.join("recs.csv");

    cli(&format!(
        "run --dataset tiny-sparse --k 4 --iters 4 --threads 2 --model {}",
        model.display()
    ))
    .unwrap();
    assert!(model.exists(), "run --model must save the factors");

    cli(&format!(
        "transform --model {} --dataset tiny-sparse --sweeps 40 --batch 8 --out {}",
        model.display(),
        hcsv.display()
    ))
    .unwrap();
    let ds = load_dataset("tiny-sparse", 42).unwrap();
    let body = std::fs::read_to_string(&hcsv).unwrap();
    let mut lines = body.lines();
    assert_eq!(lines.next().unwrap(), "doc,h0,h1,h2,h3");
    assert_eq!(body.lines().count(), 1 + ds.d(), "one row per projected doc");
    for line in body.lines().skip(1) {
        assert_eq!(line.split(',').count(), 5);
        for field in line.split(',').skip(1) {
            let x: f64 = field.parse().unwrap();
            assert!(x.is_finite() && x >= 0.0, "mixture weights are non-negative");
        }
    }

    cli(&format!(
        "recommend --model {} --dataset tiny-sparse --top 3 --exclude-seen --out {}",
        model.display(),
        rcsv.display()
    ))
    .unwrap();
    let body = std::fs::read_to_string(&rcsv).unwrap();
    assert_eq!(body.lines().next().unwrap(), "query,rank,item,score");
    assert_eq!(body.lines().count(), 1 + ds.d() * 3, "top-3 per query");

    // The excluded-seen contract, checked against the actual corpus.
    let at = match &ds.at {
        DataMatrix::Sparse(c) => c.clone(),
        _ => unreachable!("tiny-sparse is sparse"),
    };
    for line in body.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        let (q, item): (usize, u32) = (f[0].parse().unwrap(), f[2].parse().unwrap());
        let (cols, _) = at.row(q);
        assert!(
            cols.binary_search(&item).is_err(),
            "query {q} was recommended already-seen item {item}"
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn transform_rejects_mismatched_model_and_queries() {
    let dir = tmpdir("mismatch");
    let model = dir.join("model.json");
    cli(&format!("run --dataset tiny --k 3 --iters 2 --threads 1 --model {}", model.display()))
        .unwrap();
    // tiny has V=60; tiny-sparse has V=80 — projection must refuse.
    let err = cli(&format!(
        "transform --model {} --dataset tiny-sparse",
        model.display()
    ))
    .unwrap_err();
    assert!(format!("{err:#}").contains("V="), "unhelpful error: {err:#}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn transform_requires_model_option() {
    let err = cli("transform --dataset tiny").unwrap_err();
    assert!(format!("{err:#}").contains("--model"), "{err:#}");
}

#[test]
fn projector_handles_dense_datasets_too() {
    let ds = load_dataset("tiny", 5).unwrap();
    let pool = Arc::new(ThreadPool::new(2));
    let w = match &ds.a {
        DataMatrix::Dense(_) => {
            let mut rng = plnmf::util::rng::Pcg32::seeded(4);
            Mat::random(ds.v(), 5, &mut rng, 0.0, 1.0)
        }
        _ => unreachable!(),
    };
    let projector = Projector::new(w, pool, ProjectorOpts::default()).unwrap();
    let queries = match &ds.at {
        DataMatrix::Dense(m) => Queries::Dense(m),
        _ => unreachable!(),
    };
    let h = projector.project(queries).unwrap();
    assert_eq!((h.rows(), h.cols()), (ds.d(), 5));
    assert!(h.data().iter().all(|&x| x >= 0.0));
}

//! End-to-end shard-router tests: real `plnmf serve` worker *processes*
//! behind a `plnmf route` front, plus the process-location-agnostic
//! external-worker mode.
//!
//! The headline assertions:
//!
//! * **Parity** — a transform routed through the front to a worker
//!   process is bit-for-bit identical to the in-process `Projector`
//!   (the router relays worker bytes untouched, and the single-model
//!   worker runs the same pinned solver configuration).
//! * **Fault injection** — killing a worker mid-stream turns in-flight
//!   requests to that shard into `"retryable": true` errors, the
//!   supervisor restarts the worker within its backoff budget, and
//!   subsequent routed responses are again bit-for-bit identical.
//!   Synchronization is all condition-polling with deadlines — no
//!   sleeps-as-synchronization.
//! * **Replication** — a `replicas: 2` model absorbs a replica kill
//!   with ZERO client-visible failures (the retry budget fails the
//!   request over to the survivor), partial degradation is observable
//!   (`up_replicas: 1` of 2), and the supervisor restores the full
//!   replica set.

use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use plnmf::linalg::Mat;
use plnmf::nmf::Factors;
use plnmf::parallel::ThreadPool;
use plnmf::serve::registry::{manifest_json, manifest_json_replicated};
use plnmf::serve::{
    queries_to_json, save_model, Client, ModelMeta, ModelRegistry, Projector, ProjectorOpts,
    Queries, RegistryOpts, Router, RouterOpts, Server, WorkerOpts,
};
use plnmf::util::json::Json;
use plnmf::util::rng::Pcg32;
use plnmf::Elem;

/// The `plnmf` binary workers are spawned from (built by cargo for us).
const PLNMF_BIN: &str = env!("CARGO_BIN_EXE_plnmf");

fn tmpdir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("plnmf-router-it-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn write_model(dir: &Path, file: &str, v: usize, d: usize, k: usize, seed: u64) -> PathBuf {
    let f = Factors::random(v, d, k, seed);
    let path = dir.join(file);
    save_model(&path, &f, &ModelMeta::default()).unwrap();
    path
}

/// Worker knobs pinned for reproducibility: one thread, fixed sweep
/// schedule, warm cache OFF (bit-exactness needs cold solves), no
/// early-stop tolerance.
fn pinned_worker_opts(dir: &Path) -> WorkerOpts {
    let mut opts = WorkerOpts::new(PathBuf::from(PLNMF_BIN));
    opts.work_dir = dir.join("workers");
    opts.extra_args = vec![
        "--threads".into(),
        "1".into(),
        "--sweeps".into(),
        "20".into(),
        "--batch".into(),
        "8".into(),
        "--warm_cache".into(),
        "0".into(),
    ];
    opts
}

/// The in-process reference the workers must match bit-for-bit: the
/// same pinned configuration on a 1-thread pool.
fn reference_h(model: &Path, q: &Mat) -> Mat {
    let (factors, _) = plnmf::serve::load_model(model).unwrap();
    let popts = ProjectorOpts { sweeps: 20, micro_batch: 8, ..Default::default() };
    let p = Projector::new(factors.w, Arc::new(ThreadPool::new(1)), popts).unwrap();
    p.project(Queries::Dense(q)).unwrap()
}

fn h_from_json(resp: &Json, k: usize) -> Mat {
    let rows = resp.get("h").as_arr().expect("response has h");
    let mut data: Vec<Elem> = Vec::with_capacity(rows.len() * k);
    for row in rows {
        let row = row.as_arr().unwrap();
        assert_eq!(row.len(), k);
        for x in row {
            data.push(x.as_f64().unwrap() as Elem);
        }
    }
    Mat::from_vec(rows.len(), k, data)
}

fn transform_req(model: &str, q: &Mat) -> Json {
    Json::obj(vec![
        ("op", Json::str("transform")),
        ("model", Json::str(model)),
        ("queries", queries_to_json(Queries::Dense(q))),
    ])
}

/// Poll `cond` until it holds or `deadline` passes (tight loop with a
/// small pause; the pause bounds CPU, not the synchronization).
fn wait_until(deadline: Duration, what: &str, mut cond: impl FnMut() -> bool) {
    let end = Instant::now() + deadline;
    while !cond() {
        assert!(Instant::now() < end, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

type RouterHandle = std::thread::JoinHandle<anyhow::Result<()>>;

fn start_router(router: Router) -> (SocketAddr, RouterHandle) {
    let addr = router.local_addr();
    let handle = std::thread::spawn(move || router.run());
    (addr, handle)
}

fn shutdown_router(addr: SocketAddr) {
    let mut c = Client::connect(addr).unwrap();
    let resp = c.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
    assert_eq!(resp.get("bye").as_bool(), Some(true));
}

#[test]
fn routed_workers_match_in_process_bit_for_bit() {
    let dir = tmpdir("parity");
    let model_a = write_model(&dir, "a.json", 40, 9, 5, 1);
    let model_b = write_model(&dir, "b.json", 30, 9, 4, 2);
    let manifest = dir.join("fleet.json");
    std::fs::write(
        &manifest,
        manifest_json(1, 0, &[("a", "a.json"), ("b", "b.json")]).pretty(),
    )
    .unwrap();

    let router =
        Router::from_manifest(&manifest, pinned_worker_opts(&dir), RouterOpts::default())
            .unwrap();
    assert_eq!(router.names(), vec!["a", "b"]);
    let (addr, handle) = start_router(router);
    let mut client = Client::connect(addr).unwrap();

    // Both shards answer on one socket, bit-identical to in-process.
    let mut rng = Pcg32::seeded(41);
    for round in 0..3 {
        for (name, model, v, k) in [("a", &model_a, 40, 5), ("b", &model_b, 30, 4)] {
            let q = Mat::random(6, v, &mut rng, 0.0, 1.0);
            let resp = client.request_ok(&transform_req(name, &q)).unwrap();
            assert_eq!(
                h_from_json(&resp, k),
                reference_h(model, &q),
                "{name} round {round}: routed h must be bit-identical"
            );
        }
    }

    // Aggregated stats: merged per-model map + per-worker health.
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("router").as_bool(), Some(true));
    // The kernel backend surfaces at the router level, inside each
    // replica's probe info, and per merged model (keep-first merge).
    // Router and worker processes share this host's CPU and env, so
    // all three surfaces must agree.
    let backend = stats.get("kernels").as_str().expect("router stats carry 'kernels'");
    assert!(["scalar", "avx2+fma"].contains(&backend), "{stats}");
    for name in ["a", "b"] {
        let w = stats.get("workers").get(name);
        assert_eq!(w.get("up").as_bool(), Some(true), "{name}: {stats}");
        assert_eq!(w.get("restarts").as_usize(), Some(0));
        assert!(w.get("addr").as_str().unwrap().contains(':'));
        let reps = w.get("replica_stats").as_arr().unwrap();
        for r in reps {
            assert_eq!(r.get("kernels").as_str(), Some(backend), "{name}: {stats}");
        }
        let m = stats.get("models").get(name);
        assert!(m.get("requests").as_usize().unwrap() >= 3, "{name}: {stats}");
        assert_eq!(m.get("kernels").as_str(), Some(backend), "{name}: {stats}");
    }

    // Routed-mode guidance for fleet mutations.
    let resp = client
        .request(&Json::obj(vec![
            ("op", Json::str("unload")),
            ("name", Json::str("a")),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").as_bool(), Some(false));
    assert!(resp.get("error").as_str().unwrap().contains("manifest"));
    // Unknown model names the routed fleet.
    let q = Mat::from_fn(1, 40, |_, j| j as Elem);
    let resp = client.request(&transform_req("ghost", &q)).unwrap();
    assert!(resp.get("error").as_str().unwrap().contains("no model 'ghost' routed"));

    drop(client);
    shutdown_router(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn worker_crash_is_retryable_then_restarts_with_identical_results() {
    let dir = tmpdir("fault");
    let model = write_model(&dir, "m.json", 30, 9, 4, 3);
    let manifest = dir.join("fleet.json");
    std::fs::write(&manifest, manifest_json(1, 0, &[("m", "m.json")]).pretty()).unwrap();

    // Backoff wide enough that the crash→retryable-error window cannot
    // race the supervisor's restart; health interval tight so crash
    // *detection* is fast.
    let opts = RouterOpts {
        restart_backoff: Duration::from_millis(1500),
        health_interval: Duration::from_millis(50),
        ..Default::default()
    };
    let router = Router::from_manifest(&manifest, pinned_worker_opts(&dir), opts).unwrap();
    let (addr, handle) = start_router(router);
    let mut client = Client::connect(addr).unwrap();

    // A successful round trip first: establishes the pooled router →
    // worker connection and the reference answer.
    let mut rng = Pcg32::seeded(42);
    let q = Mat::random(5, 30, &mut rng, 0.0, 1.0);
    let h_ref = reference_h(&model, &q);
    let resp = client.request_ok(&transform_req("m", &q)).unwrap();
    assert_eq!(h_from_json(&resp, 4), h_ref, "pre-crash routed h");

    // Kill the worker out-of-band (protocol shutdown straight to its
    // port — the router is not involved), then wait until its listener
    // is provably gone.
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    let worker_addr: SocketAddr =
        stats.get("workers").get("m").get("addr").as_str().unwrap().parse().unwrap();
    {
        let mut direct = Client::connect(worker_addr).unwrap();
        let bye = direct.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        assert_eq!(bye.get("bye").as_bool(), Some(true));
    }
    wait_until(Duration::from_secs(30), "worker listener to close", || {
        std::net::TcpStream::connect(worker_addr).is_err()
    });

    // In-flight-style request against the dead shard: the router's
    // pooled connection is now severed, and the restart backoff keeps
    // the worker down — so this deterministically surfaces the
    // retryable error (never a hang, never a silent retry).
    let resp = client.request(&transform_req("m", &q)).unwrap();
    assert_eq!(resp.get("ok").as_bool(), Some(false), "{resp}");
    assert_eq!(resp.get("retryable").as_bool(), Some(true), "{resp}");
    assert_eq!(resp.get("model").as_str(), Some("m"));
    assert!(resp.get("error").as_str().unwrap().contains("shard 'm'"), "{resp}");

    // The supervisor restarts the worker within its backoff budget…
    wait_until(Duration::from_secs(60), "worker restart", || {
        let ping = client.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
        ping.get("workers").get("m").get("up").as_bool() == Some(true)
    });
    // …on a fresh port, with the restart counted.
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert!(stats.get("workers").get("m").get("restarts").as_usize().unwrap() >= 1);

    // And the routed answer is bit-for-bit what it was before the crash.
    let resp = client.request_ok(&transform_req("m", &q)).unwrap();
    assert_eq!(h_from_json(&resp, 4), h_ref, "post-restart routed h");

    drop(client);
    shutdown_router(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn replicated_shard_absorbs_replica_kill_with_zero_failed_requests() {
    let dir = tmpdir("replicated");
    let model = write_model(&dir, "m.json", 30, 9, 4, 10);
    let manifest = dir.join("fleet.json");
    std::fs::write(&manifest, manifest_json_replicated(1, 0, &[("m", "m.json", 2)]).pretty())
        .unwrap();

    // Wide backoff: the killed replica stays down through the traffic
    // window, so the test observes BOTH the degraded (1-of-2) fleet
    // and the zero-failure absorption. Tight health interval for fast
    // crash detection. The default route_retries = 1 is the machinery
    // under test: a failed forward fails over to the survivor.
    let opts = RouterOpts {
        restart_backoff: Duration::from_millis(2500),
        health_interval: Duration::from_millis(50),
        ..Default::default()
    };
    let router = Router::from_manifest(&manifest, pinned_worker_opts(&dir), opts).unwrap();
    let (addr, handle) = start_router(router);
    let mut client = Client::connect(addr).unwrap();

    // Reference answer (pinned solver config, warm cache off — every
    // replica must answer bit-identically).
    let mut rng = Pcg32::seeded(45);
    let q = Mat::random(5, 30, &mut rng, 0.0, 1.0);
    let h_ref = reference_h(&model, &q);
    let resp = client.request_ok(&transform_req("m", &q)).unwrap();
    assert_eq!(h_from_json(&resp, 4), h_ref, "pre-kill routed h");

    // Both replicas visible and live before the kill.
    let ping = client.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    assert_eq!(ping.get("workers").get("m").get("replicas").as_usize(), Some(2), "{ping}");
    assert_eq!(ping.get("workers").get("m").get("up_replicas").as_usize(), Some(2), "{ping}");

    // Continuous traffic on its own connection. Every response must be
    // ok AND bit-identical; failures are collected (not panicked) so
    // the main thread can assert exactly zero at the end.
    let stop = Arc::new(AtomicBool::new(false));
    let done = Arc::new(AtomicU64::new(0));
    let failures = Arc::new(Mutex::new(Vec::<String>::new()));
    let traffic = {
        let stop = Arc::clone(&stop);
        let done = Arc::clone(&done);
        let failures = Arc::clone(&failures);
        let q = q.clone();
        let h_ref = h_ref.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            let req = transform_req("m", &q);
            while !stop.load(Ordering::SeqCst) {
                match c.request(&req) {
                    Ok(resp) if resp.get("ok").as_bool() == Some(true) => {
                        if h_from_json(&resp, 4) != h_ref {
                            failures.lock().unwrap().push(format!("h mismatch: {resp}"));
                        }
                    }
                    Ok(resp) => failures.lock().unwrap().push(format!("not ok: {resp}")),
                    Err(e) => failures.lock().unwrap().push(format!("client error: {e:#}")),
                }
                done.fetch_add(1, Ordering::SeqCst);
            }
        })
    };
    wait_until(Duration::from_secs(30), "pre-kill traffic", || {
        done.load(Ordering::SeqCst) > 3
    });

    // Kill replica 0 out-of-band (protocol shutdown straight to its
    // port — the router is not involved) and wait until its listener
    // is provably gone.
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    let victim: SocketAddr = {
        let reps = stats.get("workers").get("m").get("replica_stats").as_arr().unwrap();
        assert_eq!(reps.len(), 2, "{stats}");
        reps[0].get("addr").as_str().unwrap().parse().unwrap()
    };
    {
        let mut direct = Client::connect(victim).unwrap();
        let bye = direct.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
        assert_eq!(bye.get("bye").as_bool(), Some(true));
    }
    wait_until(Duration::from_secs(30), "victim listener to close", || {
        std::net::TcpStream::connect(victim).is_err()
    });

    // Partial degradation is observable — the pre-replication `up`
    // flag hid this — while the shard stays up on the survivor…
    wait_until(Duration::from_secs(30), "degraded liveness (1 of 2)", || {
        let ping = client.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
        let m = ping.get("workers").get("m");
        m.get("up").as_bool() == Some(true) && m.get("up_replicas").as_usize() == Some(1)
    });
    // …and traffic keeps flowing while the replica is down.
    let at_kill = done.load(Ordering::SeqCst);
    wait_until(Duration::from_secs(60), "post-kill traffic", || {
        done.load(Ordering::SeqCst) > at_kill + 10
    });

    // The supervisor restores the full replica set within its backoff.
    wait_until(Duration::from_secs(60), "replica restart (2 of 2)", || {
        let ping = client.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
        ping.get("workers").get("m").get("up_replicas").as_usize() == Some(2)
    });

    stop.store(true, Ordering::SeqCst);
    traffic.join().unwrap();
    let failures = failures.lock().unwrap();
    assert!(
        failures.is_empty(),
        "replica kill leaked {} client-visible failure(s): {:?}",
        failures.len(),
        *failures
    );

    // The restart is counted, and answers stay bit-identical.
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert!(stats.get("workers").get("m").get("restarts").as_usize().unwrap() >= 1, "{stats}");
    let resp = client.request_ok(&transform_req("m", &q)).unwrap();
    assert_eq!(h_from_json(&resp, 4), h_ref, "post-restart routed h");

    drop(client);
    shutdown_router(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn binary_routed_transforms_match_json_over_replicated_processes() {
    // The v2 acceptance assertion at the routed layer: binary frames
    // relayed bytes-untouched through the replicated router (over real
    // worker processes) answer bit-for-bit what the JSON protocol and
    // the in-process reference answer.
    let dir = tmpdir("binary");
    let model = write_model(&dir, "m.json", 30, 9, 4, 12);
    let manifest = dir.join("fleet.json");
    std::fs::write(&manifest, manifest_json_replicated(1, 0, &[("m", "m.json", 2)]).pretty())
        .unwrap();
    let router =
        Router::from_manifest(&manifest, pinned_worker_opts(&dir), RouterOpts::default())
            .unwrap();
    let (addr, handle) = start_router(router);

    let mut json_client = Client::connect(addr).unwrap();
    let mut bin_client = Client::connect(addr).unwrap();
    assert_eq!(bin_client.negotiate().unwrap(), 2, "the router answers hello itself");

    let mut rng = Pcg32::seeded(46);
    for round in 0..4 {
        let q = Mat::random(5, 30, &mut rng, 0.0, 1.0);
        let h_ref = reference_h(&model, &q);
        let (h_json, res_json, _) = json_client.transform_dense("m", &q, false).unwrap();
        let (h_bin, res_bin, _) = bin_client.transform_dense("m", &q, false).unwrap();
        assert_eq!(h_json, h_ref, "round {round}: routed JSON h");
        assert_eq!(h_bin, h_ref, "round {round}: routed binary h (relayed bytes-untouched)");
        assert_eq!(res_bin, res_json, "round {round}: residuals");
        let rec_json = json_client.recommend_dense("m", &q, 4, false, false).unwrap();
        let rec_bin = bin_client.recommend_dense("m", &q, 4, false, false).unwrap();
        assert_eq!(rec_bin.get("recs"), rec_json.get("recs"), "round {round}: recs");
    }

    // Unknown model via a binary frame gets the standard routed error
    // (a JSON line, like every protocol error).
    let q = Mat::from_fn(1, 30, |_, _| 1.0);
    let err = format!("{:#}", bin_client.transform_dense("ghost", &q, false).unwrap_err());
    assert!(err.contains("no model 'ghost' routed"), "{err}");

    drop(json_client);
    drop(bin_client);
    shutdown_router(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn replicated_update_swaps_epochs_under_load_with_zero_failed_requests() {
    // The hot-swap tentpole over real worker processes: a `replicas: 2`
    // model takes sustained transform traffic while `update` batches
    // publish new factor epochs through the router. Every `update` fans
    // out to BOTH replicas (so their factors never fork), no transform
    // ever fails or hangs across a swap, and once an update has been
    // acknowledged the routed answer is bit-identical to an in-process
    // registry folded through the same batches.
    let dir = tmpdir("swap");
    let model = write_model(&dir, "m.json", 30, 9, 4, 15);
    let manifest = dir.join("fleet.json");
    std::fs::write(&manifest, manifest_json_replicated(1, 0, &[("m", "m.json", 2)]).pretty())
        .unwrap();
    let router =
        Router::from_manifest(&manifest, pinned_worker_opts(&dir), RouterOpts::default())
            .unwrap();
    let (addr, handle) = start_router(router);
    let mut client = Client::connect(addr).unwrap();

    // The in-process reference: the workers' pinned configuration, fed
    // the exact same update batches with the same pinned sweep count.
    let popts = ProjectorOpts { sweeps: 20, micro_batch: 8, ..Default::default() };
    let reference = ModelRegistry::new(RegistryOpts {
        threads: 1,
        per_model_threads: 1,
        projector: popts,
        warm_cache: 0,
        max_total_nnz: 0,
        update_sweeps: 20,
    });
    reference.load("m", &model).unwrap();
    let ref_h = |q: &Mat| -> Mat {
        reference.get("m").unwrap().transform(Queries::Dense(q), false).unwrap().0
    };

    let mut rng = Pcg32::seeded(48);
    let q = Mat::random(5, 30, &mut rng, 0.0, 1.0);
    let resp = client.request_ok(&transform_req("m", &q)).unwrap();
    assert_eq!(h_from_json(&resp, 4), ref_h(&q), "epoch 0 routed h");

    // Sustained traffic on its own connection. A request may land on
    // either side of a swap (either epoch's answer is legitimate), so
    // the in-flight assertion is exactly the zero-downtime claim: every
    // response is ok. Failures are collected, not panicked.
    let stop = Arc::new(AtomicBool::new(false));
    let done = Arc::new(AtomicU64::new(0));
    let failures = Arc::new(Mutex::new(Vec::<String>::new()));
    let traffic = {
        let stop = Arc::clone(&stop);
        let done = Arc::clone(&done);
        let failures = Arc::clone(&failures);
        let q = q.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            let req = transform_req("m", &q);
            while !stop.load(Ordering::SeqCst) {
                match c.request(&req) {
                    Ok(resp) if resp.get("ok").as_bool() == Some(true) => {}
                    Ok(resp) => failures.lock().unwrap().push(format!("not ok: {resp}")),
                    Err(e) => failures.lock().unwrap().push(format!("client error: {e:#}")),
                }
                done.fetch_add(1, Ordering::SeqCst);
            }
        })
    };
    wait_until(Duration::from_secs(30), "pre-swap traffic", || {
        done.load(Ordering::SeqCst) > 3
    });

    // Three epochs over v1 JSON while the traffic hammers. An update
    // acknowledgment means every replica folded the batch, so the
    // post-swap routed answer must equal the reference fold exactly —
    // whichever replica the router picks.
    let mut upd = Client::connect(addr).unwrap();
    for epoch in 1..=3usize {
        let u = Mat::random(6, 30, &mut rng, 0.0, 1.0);
        let resp = upd.update_dense("m", &u, Some(15)).unwrap();
        assert_eq!(resp.get("epoch").as_usize(), Some(epoch), "{resp}");
        let out = reference.update("m", Queries::Dense(&u), Some(15)).unwrap();
        assert_eq!(out.epoch, epoch as u64);
        let resp = client.request_ok(&transform_req("m", &q)).unwrap();
        assert_eq!(h_from_json(&resp, 4), ref_h(&q), "epoch {epoch} routed h");
        let at = done.load(Ordering::SeqCst);
        wait_until(Duration::from_secs(30), "traffic across the swap", || {
            done.load(Ordering::SeqCst) > at + 2
        });
    }

    // A fourth epoch over PLNB v2 binary frames (the binary fan-out
    // path), answered with the standard JSON acknowledgment.
    let mut bin = Client::connect(addr).unwrap();
    assert_eq!(bin.negotiate().unwrap(), 2);
    let u = Mat::random(4, 30, &mut rng, 0.0, 1.0);
    let resp = bin.update_dense("m", &u, Some(15)).unwrap();
    assert_eq!(resp.get("epoch").as_usize(), Some(4), "{resp}");
    reference.update("m", Queries::Dense(&u), Some(15)).unwrap();
    let (h_bin, _, _) = bin.transform_dense("m", &q, false).unwrap();
    assert_eq!(h_bin, ref_h(&q), "epoch 4 routed binary h");

    stop.store(true, Ordering::SeqCst);
    traffic.join().unwrap();
    let failures = failures.lock().unwrap();
    assert!(
        failures.is_empty(),
        "epoch swaps leaked {} client-visible failure(s): {:?}",
        failures.len(),
        *failures
    );

    // Routed stats echo the swapped factor epoch (a structural field:
    // identical across replicas because the fan-out hits all of them)
    // with the full replica set still up.
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("models").get("m").get("epoch").as_usize(), Some(4), "{stats}");
    assert_eq!(stats.get("workers").get("m").get("up_replicas").as_usize(), Some(2), "{stats}");
    assert_eq!(stats.get("workers").get("m").get("restarts").as_usize(), Some(0), "{stats}");

    // A failed update is marked non-retryable on the wire: blindly
    // re-sending could fold the same batch twice into some replicas.
    let resp = client
        .request(&Json::obj(vec![
            ("op", Json::str("update")),
            ("model", Json::str("ghost")),
            ("queries", queries_to_json(Queries::Dense(&u))),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").as_bool(), Some(false), "{resp}");
    assert_eq!(resp.get("retryable").as_bool(), Some(false), "{resp}");
    assert!(resp.get("error").as_str().unwrap().contains("no model 'ghost' routed"), "{resp}");

    drop(client);
    drop(upd);
    drop(bin);
    shutdown_router(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn mixed_loss_fleet_routes_kl_and_frobenius_worker_processes() {
    // The EngineSpec headline at the routed layer: one fleet manifest, a
    // Frobenius shard and a KL-override shard, each spawned as a real
    // `plnmf serve` process. The override must ride into the worker's
    // generated manifest, and routed answers (v1 and v2 alike — the
    // router relays bytes untouched) must be bit-identical to the
    // in-process reference projector running the same spec.
    use plnmf::nmf::{EngineSpec, Loss, Solver};

    let dir = tmpdir("mixed");
    let model_fro = write_model(&dir, "fro.json", 30, 9, 4, 13);
    let model_kl = write_model(&dir, "kl.json", 30, 9, 4, 14);
    let manifest = dir.join("fleet.json");
    std::fs::write(
        &manifest,
        r#"{"format": "plnmf-manifest", "version": 1,
            "models": [{"name": "fro", "path": "fro.json"},
                       {"name": "kl", "path": "kl.json",
                        "loss": "kl", "alpha": 0.1, "l1_ratio": 1.0}]}"#,
    )
    .unwrap();
    let router =
        Router::from_manifest(&manifest, pinned_worker_opts(&dir), RouterOpts::default())
            .unwrap();
    assert_eq!(router.names(), vec!["fro", "kl"]);
    let (addr, handle) = start_router(router);

    let mut v1 = Client::connect(addr).unwrap();
    let mut v2 = Client::connect(addr).unwrap();
    assert_eq!(v2.negotiate().unwrap(), 2);

    let spec_kl = EngineSpec {
        loss: Loss::Kl,
        solver: Solver::Mu,
        alpha: 0.1,
        l1_ratio: 1.0,
        ..Default::default()
    };
    let reference = |path: &Path, spec: EngineSpec, q: &Mat| -> Mat {
        let (factors, _) = plnmf::serve::load_model(path).unwrap();
        let popts = ProjectorOpts { sweeps: 20, micro_batch: 8, ..Default::default() };
        let p = Projector::with_spec(factors.w, Arc::new(ThreadPool::new(1)), popts, spec)
            .unwrap();
        p.project(Queries::Dense(q)).unwrap()
    };

    let mut rng = Pcg32::seeded(47);
    for round in 0..3 {
        let q = Mat::random(5, 30, &mut rng, 0.0, 1.0);
        let fro_ref = reference(&model_fro, EngineSpec::default(), &q);
        let kl_ref = reference(&model_kl, spec_kl, &q);
        for (name, want) in [("fro", &fro_ref), ("kl", &kl_ref)] {
            let resp = v1.request_ok(&transform_req(name, &q)).unwrap();
            assert_eq!(h_from_json(&resp, 4), *want, "{name} round {round}: routed v1 h");
            let (h_bin, _, _) = v2.transform_dense(name, &q, false).unwrap();
            assert_eq!(h_bin, *want, "{name} round {round}: routed v2 h");
        }
        assert_ne!(fro_ref, kl_ref, "round {round}: the objectives must differ");
    }

    // Routed stats aggregate each worker's spec echo.
    let stats = v1.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("models").get("fro").get("spec").get("loss").as_str(),
        Some("frobenius"), "{stats}");
    let kl = stats.get("models").get("kl").get("spec");
    assert_eq!(kl.get("loss").as_str(), Some("kl"), "{stats}");
    assert_eq!(kl.get("alpha").as_f64(), Some(0.1));

    drop(v1);
    drop(v2);
    shutdown_router(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn manifest_hot_reload_adds_and_removes_workers_without_touching_others() {
    let dir = tmpdir("reload");
    write_model(&dir, "a.json", 25, 8, 3, 5);
    write_model(&dir, "b.json", 20, 8, 3, 6);
    write_model(&dir, "c.json", 22, 8, 3, 7);
    let manifest = dir.join("fleet.json");
    std::fs::write(
        &manifest,
        manifest_json(1, 0, &[("a", "a.json"), ("b", "b.json")]).pretty(),
    )
    .unwrap();

    let opts = RouterOpts {
        manifest_poll: Duration::from_millis(200),
        health_interval: Duration::from_millis(50),
        ..Default::default()
    };
    let router = Router::from_manifest(&manifest, pinned_worker_opts(&dir), opts).unwrap();
    let (addr, handle) = start_router(router);
    let mut client = Client::connect(addr).unwrap();

    let mut rng = Pcg32::seeded(43);
    let q = Mat::random(4, 25, &mut rng, 0.0, 1.0);
    let h_before = h_from_json(&client.request_ok(&transform_req("a", &q)).unwrap(), 3);

    // Publish version 2: drop b, add c, leave a untouched.
    std::fs::write(
        &manifest,
        manifest_json(2, 0, &[("a", "a.json"), ("c", "c.json")]).pretty(),
    )
    .unwrap();
    wait_until(Duration::from_secs(60), "manifest v2 to apply", || {
        let ping = client.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
        let workers = ping.get("workers");
        workers.get("c").get("up").as_bool() == Some(true) && workers.get("b").is_null()
    });

    // The new shard serves; the removed one is gone.
    let qc = Mat::random(2, 22, &mut rng, 0.0, 1.0);
    client.request_ok(&transform_req("c", &qc)).unwrap();
    let resp = client.request(&transform_req("b", &Mat::from_fn(1, 20, |_, _| 1.0))).unwrap();
    assert_eq!(resp.get("ok").as_bool(), Some(false));
    assert!(resp.get("error").as_str().unwrap().contains("no model 'b'"));

    // The untouched shard was never interrupted: same worker (no
    // restarts) and bit-identical answers.
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("workers").get("a").get("restarts").as_usize(), Some(0));
    assert_eq!(stats.get("manifest_version").as_usize(), Some(2));
    let h_after = h_from_json(&client.request_ok(&transform_req("a", &q)).unwrap(), 3);
    assert_eq!(h_after, h_before);

    drop(client);
    shutdown_router(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn external_workers_route_without_supervision() {
    // Workers as in-process `Server` threads addressed by host:port —
    // the multi-host shape, and proof the router is process-location-
    // agnostic (no spawning involved).
    let dir = tmpdir("external");
    let model_a = write_model(&dir, "a.json", 35, 9, 5, 8);
    let model_b = write_model(&dir, "b.json", 28, 9, 4, 9);
    let popts = ProjectorOpts { sweeps: 20, micro_batch: 8, ..Default::default() };
    let start_worker = |name: &str, path: &Path| {
        let registry = ModelRegistry::new(RegistryOpts {
            threads: 1,
            per_model_threads: 1,
            projector: popts,
            warm_cache: 0,
            max_total_nnz: 0,
            update_sweeps: 20,
        });
        registry.load(name, path).unwrap();
        let server = Server::bind(Arc::new(registry), "127.0.0.1", 0).unwrap();
        let addr = server.local_addr();
        (addr, std::thread::spawn(move || server.run()))
    };
    let (addr_a, h_a) = start_worker("a", &model_a);
    let (addr_b, h_b) = start_worker("b", &model_b);

    let router =
        Router::with_external_workers(&[("a", addr_a), ("b", addr_b)], RouterOpts::default())
            .unwrap();
    let (addr, handle) = start_router(router);
    let mut client = Client::connect(addr).unwrap();

    let mut rng = Pcg32::seeded(44);
    for (name, model, v, k) in [("a", &model_a, 35, 5), ("b", &model_b, 28, 4)] {
        let q = Mat::random(3, v, &mut rng, 0.0, 1.0);
        let resp = client.request_ok(&transform_req(name, &q)).unwrap();
        assert_eq!(h_from_json(&resp, k), reference_h(model, &q), "{name}");
    }
    let ping = client.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    assert_eq!(ping.get("router").as_bool(), Some(true));
    assert_eq!(ping.get("workers").get("a").get("up").as_bool(), Some(true));

    // Router shutdown drains and stops the whole fleet — both worker
    // server threads join cleanly.
    drop(client);
    shutdown_router(addr);
    handle.join().unwrap().unwrap();
    h_a.join().unwrap().unwrap();
    h_b.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_route_requires_a_manifest() {
    use plnmf::bench::cli_main;
    use plnmf::cli::Args;
    let args =
        Args::parse(["route".to_string(), "--route_port".to_string(), "0".to_string()]).unwrap();
    let err = format!("{:#}", cli_main(args).unwrap_err());
    assert!(err.contains("models_manifest"), "{err}");
}

//! Property tests (via `testing::PropConfig`) pinning the paper's core
//! equivalence claim at the public-API level: the tiled three-phase
//! factor update (Alg. 2) computes the same result as the naive
//! FAST-HALS update (Alg. 1) up to floating-point reassociation — across
//! random shapes, tile widths (including `tile ∤ k` and `tile > k`,
//! which must clamp), thread counts, and both update flavors.

use plnmf::linalg::gram::gram_naive;
use plnmf::linalg::Mat;
use plnmf::nmf::halsops::{update_naive, update_tiled, UpdateKind};
use plnmf::parallel::ThreadPool;
use plnmf::testing::PropConfig;
use plnmf::util::rng::Pcg32;
use plnmf::util::PhaseTimers;

fn random_problem(n: usize, k: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Pcg32::seeded(seed);
    let x = Mat::random(n, k, &mut rng, 0.0, 1.0);
    // G: Gram of a random factor — symmetric PSD, the shape the engines
    // feed the kernels.
    let f = Mat::random(n.max(k) + 3, k, &mut rng, 0.0, 1.0);
    let g = gram_naive(&f);
    let b = Mat::random(n, k, &mut rng, 0.0, 2.0);
    (x, g, b)
}

fn max_rel_diff(a: &Mat, b: &Mat) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            let (x, y) = (a.at(i, j) as f64, b.at(i, j) as f64);
            worst = worst.max((x - y).abs() / x.abs().max(y.abs()).max(1e-6));
        }
    }
    worst
}

#[test]
fn tiled_equals_naive_across_shapes_tiles_and_threads() {
    PropConfig::trials(40).run("update_tiled == update_naive", |gen| {
        let n = gen.usize_in(1, 90);
        let k = gen.usize_in(1, 17);
        // Deliberately cover tile ∤ k, tile == k, and tile > k (clamped).
        let tile = gen.usize_in(1, k + 3);
        let threads = *gen.choose(&[1usize, 2, 3, 5, 8]);
        let kind = *gen.choose(&[UpdateKind::Plain, UpdateKind::WithDiagAndNorm]);
        let seed = gen.usize_in(0, 1_000_000) as u64;

        let (x0, g, b) = random_problem(n, k, seed);
        let pool = ThreadPool::new(threads);
        let mut x_naive = x0.clone();
        let mut x_tiled = x0.clone();
        let mut scratch = Mat::zeros(n, k);
        let mut timers = PhaseTimers::new();
        update_naive(&pool, &mut x_naive, &g, &b, kind, &mut timers, "dmv");
        update_tiled(
            &pool,
            &mut x_tiled,
            &mut scratch,
            &g,
            &b,
            tile,
            kind,
            &mut timers,
            ["p1", "p2", "p3"],
        );
        let d = max_rel_diff(&x_naive, &x_tiled);
        assert!(
            d < 1e-3,
            "n={n} k={k} tile={tile} threads={threads} {kind:?}: rel diff {d}"
        );
    });
}

#[test]
fn tiled_is_thread_count_invariant() {
    // Same inputs, different pool widths: the row-sharded kernels must
    // agree across thread counts within fp tolerance (the normalized
    // flavor folds per-worker f64 partials, so tiny reassociation slack
    // is expected — and bounded).
    PropConfig::trials(16).run("update_tiled invariant in threads", |gen| {
        let n = gen.usize_in(2, 80);
        let k = gen.usize_in(2, 12);
        let tile = gen.usize_in(1, k);
        let kind = *gen.choose(&[UpdateKind::Plain, UpdateKind::WithDiagAndNorm]);
        let seed = gen.usize_in(0, 1_000_000) as u64;
        let (x0, g, b) = random_problem(n, k, seed);

        let mut outs = Vec::new();
        for threads in [1usize, 3, 7] {
            let pool = ThreadPool::new(threads);
            let mut x = x0.clone();
            let mut scratch = Mat::zeros(n, k);
            let mut timers = PhaseTimers::new();
            update_tiled(
                &pool,
                &mut x,
                &mut scratch,
                &g,
                &b,
                tile,
                kind,
                &mut timers,
                ["p1", "p2", "p3"],
            );
            outs.push(x);
        }
        assert!(max_rel_diff(&outs[0], &outs[1]) < 1e-4, "1 vs 3 threads");
        assert!(max_rel_diff(&outs[0], &outs[2]) < 1e-4, "1 vs 7 threads");
    });
}

#[test]
fn repeated_sweeps_decrease_the_nnls_objective() {
    // The serving layer runs the Plain kernel as an iterative NNLS
    // solver against a *unit-diagonal* Gram (column-normalized factor —
    // the precondition FAST-HALS maintains and the Projector restores).
    // Under that precondition each column step is the exact coordinate
    // minimizer, so every sweep must not increase ½hᵀGh − bᵀh (fp gets a
    // hair of slack). With G_tt ≠ 1 the Plain step is not a minimizer —
    // which is exactly why serving normalizes W first.
    PropConfig::trials(16).run("sweeps are monotone", |gen| {
        let n = gen.usize_in(1, 40);
        let k = gen.usize_in(1, 10);
        let tile = gen.usize_in(1, k);
        let seed = gen.usize_in(0, 1_000_000) as u64;
        let mut rng = Pcg32::seeded(seed);
        let mut f = Mat::random(n.max(k) + 3, k, &mut rng, 0.0, 1.0);
        plnmf::nmf::init::normalize_w_columns(&mut f);
        let g = gram_naive(&f);
        let b = Mat::random(n, k, &mut rng, 0.0, 2.0);
        let pool = ThreadPool::new(2);
        let mut x = Mat::zeros(n, k);
        let mut scratch = Mat::zeros(n, k);
        let mut timers = PhaseTimers::new();

        let objective = |x: &Mat| -> f64 {
            let mut total = 0.0f64;
            for i in 0..n {
                let row = x.row(i);
                let brow = b.row(i);
                for t in 0..k {
                    let mut gx = 0.0f64;
                    for j in 0..k {
                        gx += g.at(t, j) as f64 * row[j] as f64;
                    }
                    total += 0.5 * row[t] as f64 * gx - brow[t] as f64 * row[t] as f64;
                }
            }
            total
        };

        let mut prev = objective(&x);
        for sweep in 0..6 {
            update_tiled(
                &pool,
                &mut x,
                &mut scratch,
                &g,
                &b,
                tile,
                UpdateKind::Plain,
                &mut timers,
                ["p1", "p2", "p3"],
            );
            let cur = objective(&x);
            assert!(
                cur <= prev + 1e-3 * prev.abs().max(1.0),
                "sweep {sweep}: objective rose {prev} -> {cur}"
            );
            prev = cur;
        }
    });
}

//! Coordinator-level integration: comparison protocol, sharding,
//! config-file driving, CLI surface.

use plnmf::cli::Args;
use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::comparison::{common_error_targets, run_comparison};
use plnmf::coordinator::shard::{balanced_row_shards, imbalance};
use plnmf::data::load_dataset;
use plnmf::data::DataMatrix;

#[test]
fn comparison_covers_requested_engines_in_order() {
    let mut cfg = RunConfig::default();
    cfg.dataset = "tiny".into();
    cfg.k = 4;
    cfg.max_iters = 6;
    cfg.threads = 2;
    let engines = [EngineKind::Mu, EngineKind::PlNmf, EngineKind::Bpp];
    let cmp = run_comparison(&cfg, &engines).unwrap();
    let names: Vec<&str> = cmp.reports.iter().map(|r| r.engine).collect();
    assert_eq!(names, vec!["mu-cpu", "plnmf-cpu", "bpp-cpu"]);
}

#[test]
fn error_targets_are_reachable_by_all() {
    let mut cfg = RunConfig::default();
    cfg.dataset = "tiny".into();
    cfg.k = 4;
    cfg.max_iters = 15;
    cfg.threads = 2;
    let cmp = run_comparison(&cfg, &[EngineKind::PlNmf, EngineKind::Mu]).unwrap();
    let refs: Vec<_> = cmp.reports.iter().collect();
    let targets = common_error_targets(&refs, 5);
    assert_eq!(targets.len(), 5);
    for t in &targets {
        for r in &cmp.reports {
            assert!(
                r.time_to_error(*t).is_some(),
                "{} cannot reach {t}",
                r.engine
            );
        }
    }
}

#[test]
fn balanced_shards_on_paper_shaped_corpus() {
    let ds = load_dataset("20news-small", 42).unwrap();
    let DataMatrix::Sparse(a) = &ds.a else { panic!("expected sparse") };
    let shards = balanced_row_shards(a, 8);
    let ib = imbalance(a, &shards);
    assert!(ib < 1.35, "nnz imbalance {ib}");
}

#[test]
fn config_file_roundtrip_drives_run() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("plnmf-it-cfg-{}.json", std::process::id()));
    std::fs::write(
        &path,
        r#"{"dataset": "tiny", "k": 4, "engine": "fasthals", "max_iters": 5, "threads": 2}"#,
    )
    .unwrap();
    let args = Args::parse(
        ["run", "--config", path.to_str().unwrap(), "--seed", "9"].map(String::from),
    )
    .unwrap();
    let cfg = args.to_run_config().unwrap();
    assert_eq!(cfg.dataset, "tiny");
    assert_eq!(cfg.engine, EngineKind::FastHals);
    assert_eq!(cfg.seed, 9); // CLI override wins
    let r = plnmf::coordinator::Driver::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(r.iters_run(), 5);
    std::fs::remove_file(path).ok();
}

#[test]
fn cli_main_dispatches_datasets_and_model() {
    // `datasets` and `model` paths (stdout-only commands) must succeed.
    for argv in [
        vec!["datasets", "--scale", "small"],
        vec!["model", "80", "160", "240"],
        vec!["help"],
    ] {
        let args = Args::parse(argv.into_iter().map(String::from)).unwrap();
        plnmf::bench::cli_main(args).unwrap();
    }
}

#[test]
fn shipped_config_files_parse_and_validate() {
    for entry in std::fs::read_dir("configs").unwrap() {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e == "json").unwrap_or(false) {
            let cfg = RunConfig::from_file(path.to_str().unwrap())
                .unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
            cfg.validate().unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        }
    }
}

//! Cross-engine integration: all native engines on all small dataset
//! profiles, checking the paper's qualitative claims hold everywhere.

use std::sync::Arc;

use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::comparison::run_comparison;
use plnmf::coordinator::Driver;
use plnmf::data::load_dataset;
use plnmf::nmf::plnmf::PlNmfEngine;
use plnmf::nmf::NmfEngine;
use plnmf::parallel::ThreadPool;

fn cfg(dataset: &str, engine: EngineKind, k: usize, iters: usize) -> RunConfig {
    let mut c = RunConfig::default();
    c.dataset = dataset.into();
    c.engine = engine;
    c.k = k;
    c.max_iters = iters;
    c.threads = 4;
    c
}

#[test]
fn every_native_engine_converges_on_every_small_profile() {
    for dataset in ["tiny", "tiny-sparse"] {
        for engine in [EngineKind::PlNmf, EngineKind::FastHals, EngineKind::Mu, EngineKind::Bpp] {
            let mut d = Driver::from_config(&cfg(dataset, engine, 5, 12)).unwrap();
            let r = d.run().unwrap();
            assert!(
                r.final_rel_error < r.trace[0].rel_error,
                "{dataset}/{}: {} -> {}",
                engine.name(),
                r.trace[0].rel_error,
                r.final_rel_error
            );
        }
    }
}

#[test]
fn plnmf_equals_fasthals_on_all_small_datasets() {
    // Fig. 8's central claim, across every generator family. Exact
    // equality holds per update up to f32 reassociation; over many
    // iterations the max(ε,·) active-set flips chaotically amplify that
    // noise (the paper's footnote 1 makes the same observation), so we
    // assert (a) the first iterations are identical to fp precision,
    // (b) both reach the same solution quality, (c) both are monotone.
    for dataset in ["20news-small", "reuters-small", "att-small", "pie-small"] {
        let cmp = run_comparison(
            &cfg(dataset, EngineKind::PlNmf, 16, 20),
            &[EngineKind::PlNmf, EngineKind::FastHals],
        )
        .unwrap();
        let (pl, hals) = (&cmp.reports[0], &cmp.reports[1]);
        for (a, b) in pl.trace.iter().zip(&hals.trace).take(3) {
            assert!(
                (a.rel_error - b.rel_error).abs() < 1e-5,
                "{dataset} iter {}: {} vs {}",
                a.iter,
                a.rel_error,
                b.rel_error
            );
        }
        let (ep, eh) = (pl.final_rel_error, hals.final_rel_error);
        assert!(
            (ep - eh).abs() < 0.01 || (ep - eh).abs() / eh < 0.05,
            "{dataset}: final quality differs: plnmf {ep} vs hals {eh}"
        );
        for r in [pl, hals] {
            for w in r.trace.windows(2) {
                assert!(w[1].rel_error <= w[0].rel_error + 1e-4, "{dataset} non-monotone");
            }
        }
    }
}

#[test]
fn thread_count_does_not_change_trajectories() {
    // The parallelization must be numerically stable: same trace shape
    // for 1 and 8 workers (fp-level tolerance; reductions are f64).
    let mut traces = Vec::new();
    for threads in [1, 8] {
        let mut c = cfg("tiny-sparse", EngineKind::PlNmf, 6, 10);
        c.threads = threads;
        let r = Driver::from_config(&c).unwrap().run().unwrap();
        traces.push(r.trace);
    }
    for (a, b) in traces[0].iter().zip(&traces[1]) {
        assert!(
            (a.rel_error - b.rel_error).abs() < 1e-3,
            "iter {}: {} vs {}",
            a.iter,
            a.rel_error,
            b.rel_error
        );
    }
}

#[test]
fn seeds_give_different_but_converging_runs() {
    let mut finals = Vec::new();
    for seed in [1u64, 2, 3] {
        let mut c = cfg("tiny", EngineKind::PlNmf, 4, 10);
        c.seed = seed;
        let r = Driver::from_config(&c).unwrap().run().unwrap();
        assert!(r.final_rel_error < r.trace[0].rel_error);
        finals.push(r.final_rel_error);
    }
    assert!(
        finals.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-9),
        "different seeds produced identical runs: {finals:?}"
    );
}

#[test]
fn tile_width_sweep_preserves_solution_quality() {
    // The Fig. 6 sweep varies T for performance only — quality must not
    // change (associativity).
    let ds = Arc::new(load_dataset("tiny", 9).unwrap());
    let pool = Arc::new(ThreadPool::new(2));
    let mut finals = Vec::new();
    for tile in [1, 2, 4, 8] {
        let mut e = PlNmfEngine::new(ds.clone(), pool.clone(), 8, 7, tile, 35 << 20);
        let trace = e.run(10, 10, 0.0).unwrap();
        finals.push(trace.last().unwrap().rel_error);
    }
    for w in finals.windows(2) {
        assert!((w[0] - w[1]).abs() < 2e-3, "{finals:?}");
    }
}

#[test]
fn early_stopping_tolerance_cuts_iterations() {
    let mut c = cfg("tiny", EngineKind::PlNmf, 4, 200);
    c.tol = 1e-3;
    let r = Driver::from_config(&c).unwrap().run().unwrap();
    assert!(
        r.iters_run() < 200,
        "tolerance should stop early, ran {}",
        r.iters_run()
    );
}

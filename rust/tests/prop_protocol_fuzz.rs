//! Wire-layer property and fuzz tests: the JSON codec under the NDJSON
//! protocol must round-trip every value it can express, and hostile
//! input — truncated, garbage, or oversized frames — must yield
//! protocol *errors*, never panics, hangs, or unbounded buffering.
//!
//! Deterministic (seeded `PropConfig`) so failures replay; scale trials
//! up with `PLNMF_PROP_TRIALS` for soak runs.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use plnmf::linalg::Mat;
use plnmf::nmf::Factors;
use plnmf::serve::{
    queries_to_json, save_model, wire, BinOp, Client, ModelMeta, ModelRegistry, ProjectorOpts,
    Queries, RegistryOpts, Server, MAX_LINE_BYTES,
};
use plnmf::testing::{Gen, PropConfig};
use plnmf::util::json::Json;
use plnmf::Elem;

// ---------------------------------------------------------------------------
// Json::parse_prefix ↔ serializer properties.
// ---------------------------------------------------------------------------

/// A random JSON value: nested arrays/objects with bounded depth and
/// width, scalars drawn from the value classes the protocol carries
/// (finite numbers — the serializer's contract — plus strings with
/// escapes and non-ASCII, bools, nulls).
fn random_json(g: &mut Gen, depth: usize) -> Json {
    let leaf_only = depth == 0;
    let pick = g.usize_in(0, if leaf_only { 4 } else { 6 });
    match pick {
        0 => Json::Null,
        1 => Json::Bool(g.usize_in(0, 1) == 1),
        2 => {
            // Integers (printed without fraction), fractions, exponents,
            // negatives — every number shape the serializer emits.
            let x = match g.usize_in(0, 3) {
                0 => g.usize_in(0, 1_000_000) as f64,
                1 => -(g.usize_in(0, 1_000_000) as f64),
                2 => g.f32_in(-1e6, 1e6) as f64,
                _ => g.f32_in(-1.0, 1.0) as f64 * 1e-20,
            };
            Json::Num(x)
        }
        3 => Json::Str(random_string(g)),
        4 => Json::Str(String::new()),
        5 => {
            let n = g.usize_in(0, 4);
            Json::Arr((0..n).map(|_| random_json(g, depth - 1)).collect())
        }
        _ => {
            let n = g.usize_in(0, 4);
            Json::Obj(
                (0..n)
                    .map(|i| (format!("{}{i}", random_string(g)), random_json(g, depth - 1)))
                    .collect(),
            )
        }
    }
}

fn random_string(g: &mut Gen) -> String {
    const ALPHABET: &[&str] =
        &["a", "B", "7", " ", "\"", "\\", "\n", "\t", "\r", "{", "[", ",", "é", "≤", "\u{1}"];
    let n = g.usize_in(0, 8);
    (0..n).map(|_| *g.choose(ALPHABET)).collect()
}

#[test]
fn prop_parse_prefix_roundtrips_serializer() {
    PropConfig::trials(200).run("parse_prefix ∘ to_string == id", |g| {
        let v = random_json(g, 3);
        let s = v.to_string();
        let (re, consumed) = Json::parse_prefix(&s).unwrap_or_else(|e| {
            panic!("serialized value failed to parse: {e}\n  value: {s}")
        });
        assert_eq!(consumed, s.len(), "prefix parse must consume the whole serialization");
        assert_eq!(re, v, "roundtrip changed the value: {s}");
        // The pretty form parses back to the same value too.
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v, "pretty roundtrip: {s}");
    });
}

#[test]
fn prop_parse_prefix_streams_with_trailing_data() {
    PropConfig::trials(100).run("prefix parse leaves the tail", |g| {
        let v = random_json(g, 2);
        let tail = " {\"op\": \"next\"}";
        let s = format!("{v}{tail}");
        let (re, consumed) = Json::parse_prefix(&s).unwrap();
        assert_eq!(re, v);
        assert_eq!(&s[consumed..], tail);
    });
}

#[test]
fn prop_truncated_input_errors_never_panics() {
    PropConfig::trials(200).run("truncation is an error, not a panic", |g| {
        let v = random_json(g, 3);
        let s = v.to_string();
        if s.len() < 2 {
            return;
        }
        // Truncate at a random char boundary strictly inside the text.
        let mut cut = g.usize_in(1, s.len() - 1);
        while !s.is_char_boundary(cut) {
            cut -= 1;
        }
        let t = &s[..cut];
        // A truncated *composite* must error; a truncated scalar may
        // legitimately parse shorter (e.g. "12" from "123"). Neither
        // may panic, hang, or report consuming more than it got.
        match Json::parse_prefix(t) {
            Ok((_, consumed)) => assert!(consumed <= t.len()),
            Err(e) => assert!(e.pos <= t.len()),
        }
        if matches!(v, Json::Arr(_) | Json::Obj(_)) && !t.is_empty() {
            assert!(Json::parse(t).is_err(), "truncated composite parsed: {t:?} from {s:?}");
        }
    });
}

#[test]
fn prop_garbage_bytes_error_never_panic() {
    PropConfig::trials(300).run("garbage in, error out", |g| {
        const BYTES: &[&str] = &[
            "{", "}", "[", "]", ",", ":", "\"", "\\", "tru", "nul", "-", "+", "e", "E", ".",
            "1", "9", "∞", "x", " ", "\t", "{}", "[]", "\"\"", "0x1", "1.2.3", "--1",
        ];
        let n = g.usize_in(0, 12);
        let s: String = (0..n).map(|_| *g.choose(BYTES)).collect();
        // Must terminate with Ok or Err — no panic, no hang.
        let _ = Json::parse(&s);
        let _ = Json::parse_prefix(&s);
    });
}

// ---------------------------------------------------------------------------
// The live server codec under hostile bytes.
// ---------------------------------------------------------------------------

fn tmp_model() -> PathBuf {
    // Unique per call: tests run concurrently in one process, and a
    // shared file would race its own creation.
    static COUNTER: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let n = COUNTER.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
    let dir = std::env::temp_dir().join(format!("plnmf-fuzz-{}-{n}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.json");
    let f = Factors::random(20, 6, 3, 1);
    save_model(&path, &f, &ModelMeta::default()).unwrap();
    path
}

fn start_server() -> (std::net::SocketAddr, std::thread::JoinHandle<anyhow::Result<()>>) {
    let registry = ModelRegistry::new(RegistryOpts {
        threads: 1,
        per_model_threads: 1,
        projector: ProjectorOpts::default(),
        warm_cache: 0,
        max_total_nnz: 0,
        update_sweeps: 20,
    });
    registry.load("m", &tmp_model()).unwrap();
    let server = Server::bind(Arc::new(registry), "127.0.0.1", 0).unwrap();
    let addr = server.local_addr();
    (addr, std::thread::spawn(move || server.run()))
}

fn shutdown_server(addr: std::net::SocketAddr) {
    let mut c = Client::connect(addr).unwrap();
    c.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
}

#[test]
fn server_answers_every_garbage_line_with_an_error() {
    let (addr, handle) = start_server();
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut line = String::new();
        // Deterministic garbage: every line must get one JSON error
        // response, and the connection must stay usable throughout.
        let cases: &[&str] = &[
            "garbage",
            "{\"op\": \"transform\"",          // truncated frame
            "{\"op\": \"ping\"} {\"op\": 1}",  // two values on one line
            "[1, 2, 3]",                       // not an object op
            "\u{0}\u{1}\u{2}",                 // control bytes
            "{\"op\": \"explode\"}",           // unknown op
            "123",
        ];
        for case in cases {
            w.write_all(case.as_bytes()).unwrap();
            w.write_all(b"\n").unwrap();
            line.clear();
            r.read_line(&mut line).unwrap();
            let resp = Json::parse(line.trim()).unwrap_or_else(|e| {
                panic!("non-JSON response to {case:?}: {e} ({line:?})")
            });
            assert_eq!(resp.get("ok").as_bool(), Some(false), "{case:?} -> {line:?}");
        }
        // Still serving real requests on the same connection.
        w.write_all(b"{\"op\": \"ping\"}\n").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(line.trim()).unwrap().get("pong").as_bool(), Some(true));
    }
    shutdown_server(addr);
    handle.join().unwrap().unwrap();
}

#[test]
fn oversized_frame_gets_protocol_error_not_a_hang() {
    let (addr, handle) = start_server();
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        // Exactly one byte past the cap, no newline: the server must
        // answer (bounded read) instead of buffering forever. Sending
        // not a byte more keeps the close graceful — the cap trips on
        // our very last byte, so no unread data can turn the server's
        // close into a response-discarding reset.
        let chunk = vec![b'a'; 1 << 20];
        let mut remaining = MAX_LINE_BYTES + 1;
        while remaining > 0 {
            let n = remaining.min(chunk.len());
            w.write_all(&chunk[..n]).unwrap();
            remaining -= n;
        }
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(false));
        assert!(
            resp.get("error").as_str().unwrap().contains("exceeds"),
            "unexpected error: {line}"
        );
        // The connection is closed after an oversized frame (no
        // resync possible): the next read sees EOF.
        line.clear();
        assert_eq!(r.read_line(&mut line).unwrap(), 0, "connection should be closed");
    }
    // A fresh connection still works.
    let mut c = Client::connect(addr).unwrap();
    let resp = c.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    assert_eq!(resp.get("pong").as_bool(), Some(true));
    drop(c);
    shutdown_server(addr);
    handle.join().unwrap().unwrap();
}

#[test]
fn client_surfaces_closed_mid_response_distinctly() {
    // A fake daemon that reads the request and slams the connection
    // shut without answering: `Client::request` must fail with the
    // *distinct* closed-mid-response error, not a generic read failure
    // (the router keys its retryable classification off this).
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut r = BufReader::new(stream);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        // Dropping both handles closes the socket with no response.
    });
    let mut client = Client::connect(addr).unwrap();
    let err = client.request(&Json::obj(vec![("op", Json::str("ping"))])).unwrap_err();
    assert!(
        Client::is_connection_closed(&err),
        "want the distinct closed-mid-response error, got: {err:#}"
    );
    server.join().unwrap();

    // A daemon that dies after writing *half* a response line (no
    // newline) is the same closed class — truncated bytes must never
    // be handed back as a complete response.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        w.write_all(b"{\"ok\": tr").unwrap(); // half a response, then close
    });
    let mut client = Client::connect(addr).unwrap();
    let err = client.request(&Json::obj(vec![("op", Json::str("ping"))])).unwrap_err();
    assert!(
        Client::is_connection_closed(&err),
        "a truncated response line is the closed class: {err:#}"
    );
    server.join().unwrap();

    // Contrast: a daemon that *answers* garbage is a different error
    // class (bad response JSON, not a closed connection).
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        w.write_all(b"not json\n").unwrap();
    });
    let mut client = Client::connect(addr).unwrap();
    let err = client.request(&Json::obj(vec![("op", Json::str("ping"))])).unwrap_err();
    assert!(
        !Client::is_connection_closed(&err),
        "bad-JSON responses are not the closed class: {err:#}"
    );
    server.join().unwrap();
}

// ---------------------------------------------------------------------------
// PLNB v2 binary codec properties and live-socket fuzz.
// ---------------------------------------------------------------------------

#[test]
fn prop_binary_codec_roundtrips_random_shapes() {
    PropConfig::trials(200).run("PLNB decode ∘ encode == id", |g| {
        let rows = g.usize_in(0, 20);
        let cols = g.usize_in(0, 20);
        let data: Vec<Elem> = (0..rows * cols).map(|_| g.f32_in(-1e6, 1e6)).collect();
        let model: String =
            (0..g.usize_in(0, 12)).map(|_| *g.choose(&["a", "B", "7", "é"])).collect();
        let meta = if g.bool() { Json::Null } else { random_json(g, 2) };
        let op = *g.choose(&[BinOp::Transform, BinOp::Recommend, BinOp::TransformResp]);
        let bytes = wire::encode(op, &model, &meta, rows, cols, &data).unwrap();
        let f = wire::decode(&bytes).unwrap();
        assert_eq!(f.op, op);
        assert_eq!(f.model, model);
        assert_eq!(f.meta, meta);
        assert_eq!((f.rows, f.cols), (rows, cols));
        assert_eq!(f.data, data, "raw f32 payload must survive bit-for-bit");
        let (pop, pmodel) = wire::peek_route(&bytes).unwrap();
        assert_eq!((pop, pmodel), (op, model.as_str()));
        // Any truncation fails to decode — never panics, never succeeds
        // with a short payload.
        if bytes.len() > 1 {
            let cut = g.usize_in(1, bytes.len() - 1);
            assert!(wire::decode(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    });
}

#[test]
fn binary_garbage_headers_error_and_close_without_allocation_or_hang() {
    let (addr, handle) = start_server();
    // A header declaring a ~64 GiB payload: refused from the 20 header
    // bytes alone (no allocation), then the connection closes.
    let mut oversized = Vec::from(*b"PLNB");
    oversized.push(2); // version
    oversized.push(1); // transform
    oversized.extend_from_slice(&0u16.to_le_bytes());
    oversized.extend_from_slice(&0u32.to_le_bytes());
    oversized.extend_from_slice(&u32::MAX.to_le_bytes());
    oversized.extend_from_slice(&u32::MAX.to_le_bytes());
    // Bad magic after the `P`, bad version, unknown op: all fatal
    // framing errors (no resync possible mid-binary-stream).
    let mut bad_magic = vec![0u8; 20];
    bad_magic[..4].copy_from_slice(b"PXNB");
    let mut bad_version = Vec::from(*b"PLNB");
    bad_version.push(9);
    bad_version.extend_from_slice(&[0u8; 15]);
    let mut bad_op = Vec::from(*b"PLNB");
    bad_op.push(2);
    bad_op.push(0x7f);
    bad_op.extend_from_slice(&[0u8; 14]);
    for (what, case) in [
        ("oversized", &oversized),
        ("bad magic", &bad_magic),
        ("bad version", &bad_version),
        ("bad op", &bad_op),
    ] {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut line = String::new();
        w.write_all(b"{\"op\": \"hello\", \"proto\": 2}\n").unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(line.trim()).unwrap().get("proto").as_u64(), Some(2));
        w.write_all(case).unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim())
            .unwrap_or_else(|e| panic!("{what}: non-JSON response {line:?}: {e}"));
        assert_eq!(resp.get("ok").as_bool(), Some(false), "{what}: {line:?}");
        line.clear();
        assert_eq!(r.read_line(&mut line).unwrap(), 0, "{what}: connection should close");
    }
    // A truncated frame followed by a client disconnect must not wedge
    // the daemon: a fresh connection still serves.
    {
        let good =
            wire::encode(BinOp::Transform, "m", &Json::Null, 2, 20, &[1.0; 40]).unwrap();
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        w.write_all(b"{\"op\": \"hello\", \"proto\": 2}\n").unwrap();
        r.read_line(&mut line).unwrap();
        w.write_all(&good[..good.len() / 2]).unwrap();
        drop(w);
        drop(r);
        drop(stream);
    }
    let mut c = Client::connect(addr).unwrap();
    let resp = c.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    assert_eq!(resp.get("pong").as_bool(), Some(true));
    drop(c);
    shutdown_server(addr);
    handle.join().unwrap().unwrap();
}

#[test]
fn prop_v1_and_v2_frames_interleave_on_one_connection() {
    // The model behind start_server() is 20 features x 3 topics. After
    // a hello, JSON control ops, JSON transforms, binary transforms,
    // and binary recommends interleave freely on one connection — the
    // reader re-dispatches per frame off its first byte.
    let (addr, handle) = start_server();
    PropConfig::trials(15).run("v1/v2 frames interleave", |g| {
        let mut client = Client::connect(addr).unwrap();
        assert_eq!(client.negotiate().unwrap(), 2);
        for _ in 0..g.usize_in(1, 6) {
            let rows = g.usize_in(1, 4);
            let q = Mat::from_fn(rows, 20, |i, j| ((i * 7 + j) % 5) as Elem);
            match g.usize_in(0, 3) {
                0 => {
                    let resp =
                        client.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
                    assert_eq!(resp.get("pong").as_bool(), Some(true));
                }
                1 => {
                    // Plain JSON transform on the upgraded connection.
                    let resp = client
                        .request_ok(&Json::obj(vec![
                            ("op", Json::str("transform")),
                            ("model", Json::str("m")),
                            ("queries", queries_to_json(Queries::Dense(&q))),
                        ]))
                        .unwrap();
                    assert_eq!(resp.get("h").as_arr().unwrap().len(), rows);
                }
                2 => {
                    let (h, res, _) = client.transform_dense("m", &q, true).unwrap();
                    assert_eq!((h.rows(), h.cols()), (rows, 3));
                    assert_eq!(res.len(), rows);
                }
                _ => {
                    let resp = client.recommend_dense("m", &q, 3, false, true).unwrap();
                    assert_eq!(resp.get("recs").as_arr().unwrap().len(), rows);
                }
            }
        }
    });
    shutdown_server(addr);
    handle.join().unwrap().unwrap();
}

#[test]
fn invalid_utf8_frame_gets_distinct_error_not_lossy_parse() {
    // Regression: the daemon used to lossily convert invalid-UTF-8
    // frames to replacement chars and parse the guess. It must answer
    // the distinct `invalid utf-8 in frame` error instead — and, since
    // the line boundary is intact, keep serving the connection.
    let (addr, handle) = start_server();
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        w.write_all(b"{\"op\": \"\xff\xfe\"}\n").unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(false));
        assert!(
            resp.get("error").as_str().unwrap().contains("invalid utf-8 in frame"),
            "{line}"
        );
        w.write_all(b"{\"op\": \"ping\"}\n").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(line.trim()).unwrap().get("pong").as_bool(), Some(true));
    }
    shutdown_server(addr);
    handle.join().unwrap().unwrap();
}

#[test]
fn negotiate_falls_back_to_v1_against_a_pre_v2_daemon() {
    // A fake v1 daemon that answers hello as an unknown op: the client
    // auto-upgrade must settle on v1, not error — old daemons keep
    // working with new clients.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        w.write_all(b"{\"ok\": false, \"error\": \"unknown op 'hello'\"}\n").unwrap();
    });
    let mut client = Client::connect(addr).unwrap();
    assert_eq!(client.negotiate().unwrap(), 1, "fallback to v1");
    assert_eq!(client.proto(), 1);
    server.join().unwrap();
}

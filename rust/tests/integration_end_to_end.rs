//! End-to-end: the bench harness regenerating (scaled) paper artifacts,
//! asserting the paper's qualitative shapes — the same code paths the
//! `full_reproduction` example drives.

use plnmf::bench::{fig6, fig7, fig8, fig9, table5, Scale};
use plnmf::nmf::cost_model;

#[test]
fn e6_model_numbers_match_paper_exactly() {
    // §5 worked example (also unit-tested; assert here at the public API).
    let c = cost_model::cache_words(35 * 1024 * 1024);
    assert_eq!(cost_model::naive_w_update_volume(11_314, 160) as u64, 300_525_600);
    let tiled = cost_model::tiled_w_update_volume(11_314, 160, 15, c);
    assert!((tiled - 44_897_687.0).abs() < 20.0, "{tiled}");
    let ratio = cost_model::w_update_ratio(11_314, 160, 15, c);
    assert!((ratio - 6.7).abs() < 0.05);
}

#[test]
fn e1_tile_sweep_is_u_shaped_in_the_model() {
    // The measured curve is machine-dependent; the model curve must be
    // U-shaped and the sweep must straddle the minimum.
    let rows = fig6::sweep(&["tiny-sparse"], &[8], 2, 35 << 20).unwrap();
    let vols: Vec<f64> = rows.iter().map(|r| r.model_volume).collect();
    let min_idx = vols
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    assert!(vols.first().unwrap() > &vols[min_idx]);
    assert!(vols.last().unwrap() > &vols[min_idx]);
}

#[test]
fn e2_e7_comparison_runs_and_plnmf_not_slower_than_hals() {
    let out = fig7::run_datasets(&["20news-small"], &[16], Scale::Small).unwrap();
    assert!(!out.per_iter_speedups.is_empty());
    let (_, _, _sp, _sh, ratio) = &out.per_iter_speedups[0];
    // On a bandwidth-poor CI box the tiled update must still be at least
    // par with the naive DMV loop at K=16 (at larger K it wins big).
    assert!(*ratio > 0.6, "per-iter speedup {ratio}");
}

#[test]
fn e3_hals_family_identical_and_mu_behind() {
    let reports = fig8::run_datasets(&["tiny"], 8, Scale::Small).unwrap();
    let div = fig8::hals_family_divergence(&reports);
    assert!(div[0].1 < 5e-3);
    let hals = reports.iter().find(|r| r.engine == "fasthals-cpu").unwrap();
    let mu = reports.iter().find(|r| r.engine == "mu-cpu").unwrap();
    assert!(hals.final_rel_error <= mu.final_rel_error + 1e-6);
}

#[test]
fn e4_speedup_rows_well_formed() {
    // Needs artifacts; returns empty (not error) without them.
    let rows = fig9::run_datasets(&["tiny"], 8, Scale::Small).unwrap();
    for r in &rows {
        assert!(r.speedup.is_finite() && r.speedup > 0.0);
        assert!((0.0..=1.0).contains(&r.target_error));
    }
}

#[test]
fn e5_breakdown_has_paper_shape() {
    // Phases must not cost dramatically more than the DMV they replace
    // even at toy scale, and all rows must be populated.
    let t = table5::measure("20news-small", 32, 6, 3).unwrap();
    assert!(t.hals.0 > 0.0 && t.plnmf.0 > 0.0, "SpMM timed");
    assert!(t.hals.2 > 0.0, "DMV timed");
    assert!(t.plnmf.2 + t.plnmf.3 > 0.0, "phases timed");
    // SpMM and DMM are the same code in both columns — within noise.
    let spmm_ratio = t.hals.0 / t.plnmf.0.max(1e-12);
    assert!((0.2..5.0).contains(&spmm_ratio), "SpMM ratio {spmm_ratio}");
}

#[test]
fn results_csvs_written_by_bench_sweep() {
    let dir = std::env::temp_dir().join(format!("plnmf-e2e-{}", std::process::id()));
    let rows = fig6::sweep(&["tiny"], &[6], 2, 35 << 20).unwrap();
    let csv: Vec<String> = rows
        .iter()
        .map(|r| format!("{},{},{},{:.6}", r.dataset, r.k, r.tile, r.secs_per_iter))
        .collect();
    let path = dir.join("fig6_tile_size.csv");
    plnmf::bench::report::write_csv(&path, "dataset,k,tile,secs_per_iter", &csv).unwrap();
    let body = std::fs::read_to_string(&path).unwrap();
    assert!(body.starts_with("dataset,k,tile"));
    assert!(body.lines().count() > 3);
    std::fs::remove_dir_all(dir).ok();
}

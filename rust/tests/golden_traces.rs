//! Golden-trace convergence regression for every native CPU engine.
//!
//! Runs a fixed-seed, fixed-thread 10-iteration job per (engine, dataset)
//! pair and compares the `rel_error` trajectory against a snapshot at
//! `tests/golden/traces.json`, so convergence behavior cannot silently
//! drift when kernels are refactored.
//!
//! The snapshot is **self-bootstrapping locally**: on a checkout without
//! the file (or with `PLNMF_UPDATE_GOLDEN=1`) the test writes the current
//! trajectories; subsequent runs assert against it. On CI (`CI` env var
//! set, as GitHub Actions does) a missing snapshot is a **hard failure**
//! — a regression test that silently re-baselines itself on every fresh
//! checkout asserts nothing. The bootstrap still writes the file first,
//! so a CI run's artifact can be committed to resolve the failure.
//! Pinned threads + the deterministic Pcg32 init make the traces
//! machine-stable; the tolerance only absorbs floating-point
//! reassociation (e.g. a changed autovectorization width), not
//! algorithmic drift.

use std::collections::BTreeMap;
use std::path::Path;

use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::Driver;
use plnmf::util::json::Json;

const GOLDEN_PATH: &str = "tests/golden/traces.json";
const ENGINES: &[&str] = &["plnmf", "fasthals", "mu", "mukl", "bpp"];
const DATASETS: &[&str] = &["tiny", "tiny-sparse"];
const ITERS: usize = 10;
/// |got − want| ≤ TOL · max(1, |want|) per trace point.
const TOL: f64 = 2e-3;

fn run_job(key: &str, cfg: &RunConfig) -> Vec<f64> {
    let report = Driver::from_config(cfg)
        .unwrap_or_else(|e| panic!("{key}: {e:#}"))
        .run()
        .unwrap_or_else(|e| panic!("{key}: {e:#}"));
    let trace: Vec<f64> = report.trace.iter().map(|r| r.rel_error).collect();
    assert_eq!(trace.len(), ITERS + 1, "{key}: iter 0..=10 recorded");
    assert!(trace.iter().all(|e| e.is_finite()), "{key}: non-finite error in {trace:?}");
    assert!(trace[ITERS] <= trace[0], "{key}: error rose {} -> {}", trace[0], trace[ITERS]);
    trace
}

fn trajectories() -> BTreeMap<String, Vec<f64>> {
    let mut out = BTreeMap::new();
    for dataset in DATASETS {
        for engine in ENGINES {
            let mut cfg = RunConfig::default();
            cfg.dataset = dataset.to_string();
            cfg.engine = EngineKind::from_str(engine).unwrap();
            cfg.k = 4;
            cfg.max_iters = ITERS;
            cfg.record_every = 1;
            cfg.threads = 2;
            cfg.seed = 7;
            let key = format!("{engine}/{dataset}");
            let trace = run_job(&key, &cfg);
            out.insert(key, trace);
        }
    }
    // The one regularized golden job: elastic-net KL (alpha=0.1,
    // l1_ratio=0.5 — the EngineSpec surface) on the sparse corpus, so
    // the H-denominator penalty terms cannot silently drift.
    let mut cfg = RunConfig::default();
    cfg.dataset = "tiny-sparse".to_string();
    cfg.engine = EngineKind::MuKl;
    cfg.k = 4;
    cfg.max_iters = ITERS;
    cfg.record_every = 1;
    cfg.threads = 2;
    cfg.seed = 7;
    cfg.alpha = 0.1;
    cfg.l1_ratio = 0.5;
    let key = "mukl+reg/tiny-sparse";
    let trace = run_job(key, &cfg);
    assert_ne!(
        trace[ITERS], out["mukl/tiny-sparse"][ITERS],
        "{key}: the penalty changed nothing vs. the free run"
    );
    out.insert(key.to_string(), trace);
    out
}

/// CI detection: the `CI` env var is set by GitHub Actions (`true`) and
/// virtually every other CI system; `0`/`false` opt back out.
fn on_ci() -> bool {
    match std::env::var("CI") {
        Ok(v) => !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"),
        Err(_) => false,
    }
}

fn write_golden(path: &Path, traces: &BTreeMap<String, Vec<f64>>) {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).unwrap();
    }
    let obj = Json::Obj(
        traces
            .iter()
            .map(|(k, v)| {
                (k.clone(), Json::Arr(v.iter().map(|&x| Json::Num(x)).collect()))
            })
            .collect(),
    );
    std::fs::write(path, obj.pretty()).unwrap();
}

#[test]
fn convergence_trajectories_match_golden_snapshot() {
    // Pin the SIMD dispatch to the portable scalar backend: the scalar
    // kernels are bit-for-bit the pre-refactor arithmetic, so the
    // committed snapshot stays machine-independent (an AVX2 host and a
    // plain one produce identical traces). This also regression-tests
    // the `PLNMF_KERNELS` override end-to-end — it must actually force
    // scalar selection here. Safe to set: this integration test runs in
    // its own process, and env mutation happens before any pool exists.
    std::env::set_var("PLNMF_KERNELS", "scalar");
    assert_eq!(
        plnmf::kernels::Kernels::select().backend,
        plnmf::kernels::Backend::Scalar,
        "PLNMF_KERNELS=scalar must force the scalar backend"
    );
    let got = trajectories();
    let path = Path::new(GOLDEN_PATH);
    let update = std::env::var("PLNMF_UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        write_golden(path, &got);
        if !update && on_ci() {
            panic!(
                "{GOLDEN_PATH} is missing: on CI the golden-trace regression must assert, \
                 not re-baseline itself. Run `cargo test -q` locally once and commit the \
                 generated snapshot (it was just written, {} traces).",
                got.len()
            );
        }
        eprintln!(
            "golden snapshot written to {GOLDEN_PATH} ({} traces) — commit it; \
             subsequent runs assert against it",
            got.len()
        );
        return;
    }

    let body = std::fs::read_to_string(path).unwrap();
    let golden = Json::parse(&body).unwrap_or_else(|e| panic!("corrupt {GOLDEN_PATH}: {e}"));
    for (key, trace) in &got {
        let want = golden.get(key).as_arr().unwrap_or_else(|| {
            panic!("{GOLDEN_PATH} has no entry for '{key}' — set PLNMF_UPDATE_GOLDEN=1 to refresh")
        });
        assert_eq!(want.len(), trace.len(), "{key}: trace length changed");
        for (i, (&got_e, want_j)) in trace.iter().zip(want).enumerate() {
            let want_e = want_j.as_f64().unwrap();
            assert!(
                (got_e - want_e).abs() <= TOL * want_e.abs().max(1.0),
                "{key} iter {i}: rel_error {got_e} drifted from golden {want_e} \
                 (tol {TOL}; set PLNMF_UPDATE_GOLDEN=1 to accept intentional changes)"
            );
        }
    }
    // Drift guard in the other direction: a stale snapshot with extra
    // engines would silently shrink coverage.
    if let Some(obj) = golden.as_obj() {
        for key in obj.keys() {
            assert!(got.contains_key(key), "golden has '{key}' but the test no longer runs it");
        }
    }
}

//! End-to-end daemon tests: a live `plnmf serve` socket exercised by
//! concurrent clients over two registered models, protocol error paths,
//! manifest hot reload, and the warm-start contract.
//!
//! The headline assertion is **bit-for-bit parity**: a transform /
//! recommend answered over TCP + newline-delimited JSON must equal the
//! in-process `Projector` result exactly (JSON numbers are f64, which
//! carries every f32 exactly; the daemon runs each model on a pool of
//! the same width the reference uses).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use plnmf::linalg::Mat;
use plnmf::nmf::Factors;
use plnmf::parallel::ThreadPool;
use plnmf::serve::registry::manifest_json;
use plnmf::serve::{
    queries_to_json, save_model, Client, ModelMeta, ModelRegistry, Projector, ProjectorOpts,
    Queries, RegistryOpts, Server, WarmCache,
};
use plnmf::testing::PropConfig;
use plnmf::util::json::Json;
use plnmf::util::rng::Pcg32;
use plnmf::Elem;

fn tmpdir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("plnmf-daemon-it-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn write_model(dir: &Path, file: &str, v: usize, d: usize, k: usize, seed: u64) -> PathBuf {
    let f = Factors::random(v, d, k, seed);
    let path = dir.join(file);
    save_model(&path, &f, &ModelMeta::default()).unwrap();
    path
}

/// Registry options pinned for reproducibility: one thread per model, so
/// the in-process reference (also one thread) matches bit-for-bit.
fn pinned_opts(projector: ProjectorOpts, warm_cache: usize) -> RegistryOpts {
    RegistryOpts {
        threads: 2,
        per_model_threads: 1,
        projector,
        warm_cache,
        max_total_nnz: 0,
        update_sweeps: 20,
    }
}

type ServerHandle = std::thread::JoinHandle<anyhow::Result<()>>;

fn start_server(registry: ModelRegistry) -> (std::net::SocketAddr, ServerHandle) {
    let server = Server::bind(Arc::new(registry), "127.0.0.1", 0).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run());
    (addr, handle)
}

fn shutdown(addr: std::net::SocketAddr) {
    let mut c = Client::connect(addr).unwrap();
    let resp = c.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))])).unwrap();
    assert_eq!(resp.get("bye").as_bool(), Some(true));
}

/// Parse a response `h` back into a Mat of exact f32s.
fn h_from_json(resp: &Json, k: usize) -> Mat {
    let rows = resp.get("h").as_arr().expect("response has h");
    let mut data: Vec<Elem> = Vec::with_capacity(rows.len() * k);
    for row in rows {
        let row = row.as_arr().unwrap();
        assert_eq!(row.len(), k);
        for x in row {
            data.push(x.as_f64().unwrap() as Elem);
        }
    }
    Mat::from_vec(rows.len(), k, data)
}

#[test]
fn concurrent_clients_on_two_models_match_in_process_bit_for_bit() {
    let dir = tmpdir("parity");
    let model_a = write_model(&dir, "a.json", 40, 9, 5, 1);
    let model_b = write_model(&dir, "b.json", 30, 9, 4, 2);

    // Deterministic options; warm cache off for exact reproducibility.
    let popts = ProjectorOpts { sweeps: 20, micro_batch: 8, ..Default::default() };
    let registry = ModelRegistry::new(pinned_opts(popts, 0));
    registry.load("a", &model_a).unwrap();
    registry.load("b", &model_b).unwrap();
    let (addr, handle) = start_server(registry);

    // In-process references on a pool of the same width (1 thread).
    // `move` copies `popts` in, keeping the closure 'static + Copy so
    // both spawned workers can carry it.
    let reference = move |path: &Path, q: &Mat| -> (Mat, Vec<Vec<(u32, Elem)>>) {
        let (factors, _) = plnmf::serve::load_model(path).unwrap();
        let pool = Arc::new(ThreadPool::new(1));
        let p = Projector::new(factors.w, pool, popts).unwrap();
        let h = p.project(Queries::Dense(q)).unwrap();
        let recs = p.recommend_for(Queries::Dense(q), &h, 5, false).unwrap();
        (h, recs)
    };

    let worker = |name: &'static str, path: PathBuf, v: usize, k: usize, seed: u64| {
        let addr = addr;
        std::thread::spawn(move || {
            let mut rng = Pcg32::seeded(seed);
            let mut client = Client::connect(addr).unwrap();
            for round in 0..4 {
                let q = Mat::random(6, v, &mut rng, 0.0, 1.0);
                let (h_ref, recs_ref) = reference(&path, &q);

                let resp = client
                    .request_ok(&Json::obj(vec![
                        ("op", Json::str("transform")),
                        ("model", Json::str(name)),
                        ("queries", queries_to_json(Queries::Dense(&q))),
                    ]))
                    .unwrap();
                let h = h_from_json(&resp, k);
                assert_eq!(h, h_ref, "{name} round {round}: daemon h must be bit-identical");

                let resp = client
                    .request_ok(&Json::obj(vec![
                        ("op", Json::str("recommend")),
                        ("model", Json::str(name)),
                        ("queries", queries_to_json(Queries::Dense(&q))),
                        ("top", Json::num(5.0)),
                    ]))
                    .unwrap();
                let recs = resp.get("recs").as_arr().unwrap();
                assert_eq!(recs.len(), recs_ref.len());
                for (qi, (got, want)) in recs.iter().zip(&recs_ref).enumerate() {
                    let got = got.as_arr().unwrap();
                    assert_eq!(got.len(), want.len());
                    for (pair, &(item, score)) in got.iter().zip(want) {
                        let pair = pair.as_arr().unwrap();
                        assert_eq!(pair[0].as_usize().unwrap() as u32, item, "{name} q{qi}");
                        assert_eq!(pair[1].as_f64().unwrap() as Elem, score, "{name} q{qi}");
                    }
                }
            }
        })
    };

    // Two clients hammer two different models concurrently.
    let ta = worker("a", model_a.clone(), 40, 5, 77);
    let tb = worker("b", model_b.clone(), 30, 4, 78);
    ta.join().unwrap();
    tb.join().unwrap();

    shutdown(addr);
    handle.join().unwrap().unwrap(); // clean exit
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn warm_start_cuts_sweeps_and_shows_in_stats() {
    let dir = tmpdir("warm");
    let model = write_model(&dir, "m.json", 35, 9, 6, 3);
    let popts = ProjectorOpts { sweeps: 100, micro_batch: 16, tol: 1e-6, ..Default::default() };
    let registry = ModelRegistry::new(pinned_opts(popts, 128));
    registry.load("m", &model).unwrap();
    let (addr, handle) = start_server(registry);

    let mut rng = Pcg32::seeded(9);
    let q = Mat::random(10, 35, &mut rng, 0.0, 1.0);
    let req = Json::obj(vec![
        ("op", Json::str("transform")),
        ("model", Json::str("m")),
        ("queries", queries_to_json(Queries::Dense(&q))),
    ]);
    let mut client = Client::connect(addr).unwrap();

    let cold = client.request_ok(&req).unwrap();
    let cold_sweeps = cold.get("warm").get("sweeps").as_usize().unwrap();
    assert_eq!(cold.get("warm").get("hits").as_usize(), Some(0));
    assert_eq!(cold.get("warm").get("misses").as_usize(), Some(10));

    let warm = client.request_ok(&req).unwrap();
    let warm_sweeps = warm.get("warm").get("sweeps").as_usize().unwrap();
    assert_eq!(warm.get("warm").get("hits").as_usize(), Some(10));
    assert!(
        warm_sweeps <= cold_sweeps,
        "warm repeat ran {warm_sweeps} sweeps vs cold {cold_sweeps}"
    );

    // Warm result stays within the solve tolerance regime of the cold one.
    let h_cold = h_from_json(&cold, 6);
    let h_warm = h_from_json(&warm, 6);
    assert!(h_cold.max_abs_diff(&h_warm) < 1e-3);

    // The stats op shows the two buckets separately.
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    let m = stats.get("models").get("m");
    assert_eq!(m.get("cold").get("requests").as_usize(), Some(1));
    assert_eq!(m.get("warm").get("requests").as_usize(), Some(1));
    let cold_avg = m.get("cold").get("avg_sweeps").as_f64().unwrap();
    let warm_avg = m.get("warm").get("avg_sweeps").as_f64().unwrap();
    assert!(
        warm_avg <= cold_avg,
        "stats: warm avg sweeps {warm_avg} vs cold {cold_avg}"
    );
    assert_eq!(m.get("warm_hits").as_usize(), Some(10));

    // The selected SIMD kernel backend is reported at the daemon level
    // and per model (the value depends on the host CPU / env override,
    // so assert the closed name set and daemon/model agreement).
    let backend = stats.get("kernels").as_str().expect("daemon stats carry 'kernels'");
    assert!(["scalar", "avx2+fma"].contains(&backend), "{stats}");
    assert_eq!(m.get("kernels").as_str(), Some(backend), "{stats}");

    drop(client);
    shutdown(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn warm_start_property_cached_start_never_does_worse() {
    // Pure-projector property (no socket): for random problems, an exact
    // repeat with a warm cache (a) runs no more sweeps than the cold
    // solve and (b) lands within the sweep tolerance of the cold result.
    PropConfig::trials(10).run("warm start dominates cold start", |g| {
        let v = g.usize_in(10, 40);
        let k = g.usize_in(2, 7);
        let m = g.usize_in(1, 12);
        let tol = 1e-6;
        let mut rng = Pcg32::seeded(1000 + g.trial);
        let w = Mat::random(v, k, &mut rng, 0.0, 2.0);
        let q = Mat::random(m, v, &mut rng, 0.0, 1.0);
        let p = Projector::new(
            w,
            Arc::new(ThreadPool::new(2)),
            ProjectorOpts { sweeps: 150, micro_batch: 4, tol, ..Default::default() },
        )
        .unwrap();
        let mut cache = WarmCache::new(64);
        let (h_cold, cold) = p.project_warm(Queries::Dense(&q), &mut cache).unwrap();
        let (h_warm, warm) = p.project_warm(Queries::Dense(&q), &mut cache).unwrap();
        assert_eq!(cold.warm_hits, 0);
        assert_eq!(warm.warm_hits, m);
        assert!(
            warm.sweeps <= cold.sweeps,
            "v={v} k={k} m={m}: warm {} vs cold {} sweeps",
            warm.sweeps,
            cold.sweeps
        );
        assert!(h_cold.max_abs_diff(&h_warm) < 1e-3, "v={v} k={k} m={m}");
    });
}

#[test]
fn protocol_errors_leave_the_connection_usable() {
    let dir = tmpdir("errors");
    let model = write_model(&dir, "m.json", 20, 5, 3, 4);
    let registry = ModelRegistry::new(pinned_opts(ProjectorOpts::default(), 0));
    registry.load("m", &model).unwrap();
    let (addr, handle) = start_server(registry);
    let mut client = Client::connect(addr).unwrap();

    let expect_err = |client: &mut Client, req: &Json, needle: &str| {
        let resp = client.request(req).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(false), "{req}");
        let msg = resp.get("error").as_str().unwrap_or("");
        assert!(msg.contains(needle), "error {msg:?} should mention {needle:?}");
    };

    expect_err(&mut client, &Json::obj(vec![("op", Json::str("explode"))]), "unknown op");
    expect_err(&mut client, &Json::obj(vec![("no_op", Json::num(1.0))]), "op");
    expect_err(
        &mut client,
        &Json::obj(vec![
            ("op", Json::str("transform")),
            ("model", Json::str("ghost")),
            ("queries", Json::arr(vec![])),
        ]),
        "no model 'ghost'",
    );
    // Wrong feature width.
    expect_err(
        &mut client,
        &Json::obj(vec![
            ("op", Json::str("transform")),
            ("model", Json::str("m")),
            ("queries", Json::arr(vec![Json::arr(vec![Json::num(1.0)])])),
        ]),
        "expects V=20",
    );
    // Non-JSON garbage straight on the wire.
    {
        use std::io::{BufRead, BufReader, Write};
        let stream = std::net::TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        w.write_all(b"this is not json\n").unwrap();
        let mut r = BufReader::new(stream);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(false));
    }
    // The original connection still answers.
    let pong = client.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    assert_eq!(pong.get("pong").as_bool(), Some(true));

    drop(client);
    shutdown(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn load_unload_admission_and_manifest_reload_over_the_wire() {
    let dir = tmpdir("ops");
    // a.json is referenced by the manifest (relative path); b is loaded
    // explicitly over the wire.
    write_model(&dir, "a.json", 25, 6, 4, 5);
    let model_b = write_model(&dir, "b.json", 25, 6, 4, 6);
    let manifest = dir.join("manifest.json");
    std::fs::write(&manifest, manifest_json(1, 150, &[("a", "a.json")]).pretty()).unwrap();

    let registry =
        ModelRegistry::from_manifest(&manifest, pinned_opts(ProjectorOpts::default(), 0))
            .unwrap();
    let (addr, handle) = start_server(registry);
    let mut client = Client::connect(addr).unwrap();

    // Admission: a 25x4 random W is ~100 nnz; budget 150 rejects a 2nd.
    let resp = client
        .request(&Json::obj(vec![
            ("op", Json::str("load")),
            ("name", Json::str("b")),
            ("path", Json::str(model_b.display().to_string())),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").as_bool(), Some(false));
    assert!(resp.get("error").as_str().unwrap().contains("admission"));

    // Unload a, then b fits.
    client
        .request_ok(&Json::obj(vec![("op", Json::str("unload")), ("name", Json::str("a"))]))
        .unwrap();
    let resp = client
        .request_ok(&Json::obj(vec![
            ("op", Json::str("load")),
            ("name", Json::str("b")),
            ("path", Json::str(model_b.display().to_string())),
        ]))
        .unwrap();
    assert_eq!(resp.get("loaded").as_str(), Some("b"));

    // Manifest hot reload: bump version, list only a again. The wire op
    // races the server's background poller for who applies version 2
    // first, so assert on the converged state, not on `reloaded`.
    std::fs::write(&manifest, manifest_json(2, 150, &[("a", "a.json")]).pretty()).unwrap();
    let resp = client.request_ok(&Json::obj(vec![("op", Json::str("load"))])).unwrap();
    assert!(resp.get("reloaded").as_bool().is_some());
    assert_eq!(resp.get("manifest_version").as_usize(), Some(2));

    // b (not in the manifest) is gone; a serves.
    let resp = client
        .request(&Json::obj(vec![
            ("op", Json::str("transform")),
            ("model", Json::str("b")),
            ("queries", Json::arr(vec![])),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").as_bool(), Some(false));
    let q = Mat::from_fn(2, 25, |i, j| ((i + j) % 3) as Elem);
    client
        .request_ok(&Json::obj(vec![
            ("op", Json::str("transform")),
            ("model", Json::str("a")),
            ("queries", queries_to_json(Queries::Dense(&q))),
        ]))
        .unwrap();

    drop(client);
    shutdown(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn binary_protocol_matches_json_bit_for_bit() {
    // The tentpole parity assertion: the same dense batch answered over
    // v1 JSON and over negotiated PLNB v2 binary frames must be
    // bit-identical — and both must equal the in-process reference.
    let dir = tmpdir("binary");
    let model = write_model(&dir, "m.json", 40, 9, 5, 21);
    let popts = ProjectorOpts { sweeps: 20, micro_batch: 8, ..Default::default() };
    let registry = ModelRegistry::new(pinned_opts(popts, 0));
    registry.load("m", &model).unwrap();
    let (addr, handle) = start_server(registry);

    let mut json_client = Client::connect(addr).unwrap();
    let mut bin_client = Client::connect(addr).unwrap();
    assert_eq!(bin_client.negotiate().unwrap(), 2);
    assert_eq!(json_client.proto(), 1, "no hello, no upgrade");

    let mut rng = Pcg32::seeded(88);
    let mut q = Mat::random(6, 40, &mut rng, 0.0, 1.0);
    for round in 0..3 {
        let (h_json, res_json, _) = json_client.transform_dense("m", &q, false).unwrap();
        let (h_bin, res_bin, meta) = bin_client.transform_dense("m", &q, false).unwrap();
        assert_eq!(h_bin, h_json, "round {round}: binary h must be bit-identical to JSON");
        assert_eq!(res_bin, res_json, "round {round}: residuals");
        assert_eq!(meta.get("model").as_str(), Some("m"));
        let (factors, _) = plnmf::serve::load_model(&model).unwrap();
        let p = Projector::new(factors.w, Arc::new(ThreadPool::new(1)), popts).unwrap();
        assert_eq!(h_json, p.project(Queries::Dense(&q)).unwrap(), "round {round}: reference");
        // Binary recommend answers the exact recommend response JSON.
        let rec_json = json_client.recommend_dense("m", &q, 5, false, false).unwrap();
        let rec_bin = bin_client.recommend_dense("m", &q, 5, false, false).unwrap();
        assert_eq!(rec_bin.get("recs"), rec_json.get("recs"), "round {round}: recs");
        q = Mat::random(6, 40, &mut rng, 0.0, 1.0);
    }

    // Binary-level protocol errors come back as JSON lines and leave
    // the upgraded connection usable.
    let bad = Mat::from_fn(2, 7, |_, _| 1.0);
    let err = format!("{:#}", bin_client.transform_dense("m", &bad, false).unwrap_err());
    assert!(err.contains("V=40"), "{err}");
    let err = format!("{:#}", bin_client.transform_dense("ghost", &q, false).unwrap_err());
    assert!(err.contains("no model 'ghost'"), "{err}");
    let pong = bin_client.request_ok(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    assert_eq!(pong.get("pong").as_bool(), Some(true));

    drop(json_client);
    drop(bin_client);
    shutdown(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn online_update_publishes_epochs_and_matches_in_process_fold() {
    // The online-update tentpole over the wire: `update` folds a batch
    // of new rows into the factors and publishes epoch N+1, over both
    // v1 JSON and PLNB v2 binary frames, and every post-swap transform
    // is bit-identical to an in-process registry driven through the
    // exact same op sequence. The warm cache is salted by epoch, so the
    // first repeat after each swap must re-solve (0 hits).
    let dir = tmpdir("update");
    let model = write_model(&dir, "m.json", 30, 9, 4, 41);
    let popts = ProjectorOpts { sweeps: 20, micro_batch: 8, ..Default::default() };
    let registry = ModelRegistry::new(pinned_opts(popts, 64));
    registry.load("m", &model).unwrap();
    let (addr, handle) = start_server(registry);

    // The in-process reference: a second registry with the same pinned
    // options, fed the same transforms/updates in the same order (the
    // transform mirroring also keeps the two warm caches in lockstep).
    let reference = ModelRegistry::new(pinned_opts(popts, 64));
    reference.load("m", &model).unwrap();
    let ref_transform = |q: &Mat| -> Mat {
        reference.get("m").unwrap().transform(Queries::Dense(q), true).unwrap().0
    };

    let mut v1 = Client::connect(addr).unwrap();
    let mut v2 = Client::connect(addr).unwrap();
    assert_eq!(v2.negotiate().unwrap(), 2);

    let mut rng = Pcg32::seeded(404);
    let q = Mat::random(5, 30, &mut rng, 0.0, 1.0);
    let (h0, _, _) = v1.transform_dense("m", &q, true).unwrap();
    assert_eq!(h0, ref_transform(&q), "epoch 0 parity");

    // Fold new rows in over v1 JSON: epoch 0 -> 1. rows_seen counts the
    // training seed (d=9) plus the folded batch.
    let u1 = Mat::random(6, 30, &mut rng, 0.0, 1.0);
    let resp = v1.update_dense("m", &u1, None).unwrap();
    assert_eq!(resp.get("epoch").as_usize(), Some(1), "{resp}");
    assert_eq!(resp.get("rows_seen").as_usize(), Some(9 + 6), "{resp}");
    reference.update("m", Queries::Dense(&u1), None).unwrap();

    // Same question, new factors: the answer moved, and moved exactly
    // where the reference fold moved. The old epoch-0 cache entry for q
    // must NOT seed this solve (salt changed): 0 hits.
    let (h1, _, meta) = v1.transform_dense("m", &q, true).unwrap();
    assert_ne!(h1, h0, "the fold must actually change the factors");
    assert_eq!(meta.get("warm").get("hits").as_usize(), Some(0), "{meta}");
    assert_eq!(h1, ref_transform(&q), "epoch 1 parity");

    // Second update over PLNB v2 binary frames with an explicit sweep
    // count: epoch 1 -> 2, still bit-identical to the reference fold.
    let u2 = Mat::random(4, 30, &mut rng, 0.0, 1.0);
    let resp = v2.update_dense("m", &u2, Some(12)).unwrap();
    assert_eq!(resp.get("epoch").as_usize(), Some(2), "{resp}");
    assert_eq!(resp.get("rows_seen").as_usize(), Some(9 + 6 + 4), "{resp}");
    let out = reference.update("m", Queries::Dense(&u2), Some(12)).unwrap();
    assert_eq!(out.epoch, 2);
    let (h2, _, meta) = v2.transform_dense("m", &q, true).unwrap();
    assert_eq!(meta.get("warm").get("hits").as_usize(), Some(0), "post-swap repeat: {meta}");
    assert_eq!(h2, ref_transform(&q), "epoch 2 parity");
    assert_ne!(h2, h1);

    // Within one epoch the cache works as before: an exact repeat hits.
    let (_, _, meta) = v2.transform_dense("m", &q, true).unwrap();
    assert_eq!(meta.get("warm").get("hits").as_usize(), Some(5), "{meta}");
    ref_transform(&q);

    // Stats echo the live factor epoch.
    let stats = v1.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("models").get("m").get("epoch").as_usize(), Some(2), "{stats}");

    // Error paths: unknown model; present-but-zero sweeps must not
    // silently no-op (and must not bump the epoch).
    let err = format!("{:#}", v1.update_dense("ghost", &u1, None).unwrap_err());
    assert!(err.contains("no model 'ghost'"), "{err}");
    let err = format!("{:#}", v1.update_dense("m", &u1, Some(0)).unwrap_err());
    assert!(err.contains("sweeps"), "{err}");
    let stats = v1.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("models").get("m").get("epoch").as_usize(), Some(2), "{stats}");

    drop(v1);
    drop(v2);
    shutdown(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn hello_negotiation_and_strict_request_integers_over_the_wire() {
    let dir = tmpdir("hello");
    let model = write_model(&dir, "m.json", 20, 5, 3, 4);
    let registry = ModelRegistry::new(pinned_opts(ProjectorOpts::default(), 0));
    registry.load("m", &model).unwrap();
    let (addr, handle) = start_server(registry);
    let mut client = Client::connect(addr).unwrap();

    // Explicit v1 stays v1; bogus protos are loud errors; a v9 client
    // negotiates DOWN to 2, never up.
    let hello = |client: &mut Client, proto: f64| {
        client
            .request(&Json::obj(vec![("op", Json::str("hello")), ("proto", Json::num(proto))]))
            .unwrap()
    };
    let resp = hello(&mut client, 1.0);
    assert_eq!(resp.get("proto").as_u64(), Some(1), "{resp}");
    let resp = hello(&mut client, -3.0);
    assert_eq!(resp.get("ok").as_bool(), Some(false), "{resp}");
    let resp = hello(&mut client, 9.0);
    assert_eq!(resp.get("proto").as_u64(), Some(2), "{resp}");

    // Strict "top": present-but-bogus errors instead of silently
    // becoming the default 10 (the silent-coercion regression).
    let q = Mat::from_fn(1, 20, |_, j| j as Elem);
    for bad_top in [Json::num(-1.0), Json::num(2.7), Json::str("five")] {
        let resp = client
            .request(&Json::obj(vec![
                ("op", Json::str("recommend")),
                ("model", Json::str("m")),
                ("queries", queries_to_json(Queries::Dense(&q))),
                ("top", bad_top.clone()),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(false), "top={bad_top}: {resp}");
        assert!(resp.get("error").as_str().unwrap().contains("top"), "{resp}");
    }
    // Absent top still defaults.
    let resp = client
        .request_ok(&Json::obj(vec![
            ("op", Json::str("recommend")),
            ("model", Json::str("m")),
            ("queries", queries_to_json(Queries::Dense(&q))),
        ]))
        .unwrap();
    assert_eq!(resp.get("recs").as_arr().unwrap().len(), 1);

    drop(client);
    shutdown(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn mixed_loss_manifest_serves_kl_and_frobenius_side_by_side() {
    // The EngineSpec headline: ONE manifest, one daemon, a Frobenius
    // recommender and a sparse KL topic model served concurrently —
    // each answered identically over v1 NDJSON and v2 binary frames,
    // and each bit-identical to its in-process reference projector.
    use plnmf::nmf::{EngineSpec, Loss, Solver};

    let dir = tmpdir("mixed");
    let model_fro = write_model(&dir, "fro.json", 30, 9, 4, 31);
    let model_kl = write_model(&dir, "kl.json", 30, 9, 4, 32);
    let manifest = dir.join("manifest.json");
    std::fs::write(
        &manifest,
        r#"{"format": "plnmf-manifest", "version": 1,
            "models": [{"name": "fro", "path": "fro.json"},
                       {"name": "kl", "path": "kl.json",
                        "loss": "kl", "alpha": 0.1, "l1_ratio": 1.0}]}"#,
    )
    .unwrap();

    let popts = ProjectorOpts { sweeps: 30, micro_batch: 8, ..Default::default() };
    let registry = ModelRegistry::from_manifest(&manifest, pinned_opts(popts, 0)).unwrap();
    let (addr, handle) = start_server(registry);

    let mut v1 = Client::connect(addr).unwrap();
    let mut v2 = Client::connect(addr).unwrap();
    assert_eq!(v2.negotiate().unwrap(), 2);

    let spec_kl = EngineSpec {
        loss: Loss::Kl,
        solver: Solver::Mu,
        alpha: 0.1,
        l1_ratio: 1.0,
        ..Default::default()
    };
    let reference = |path: &Path, spec: EngineSpec, q: &Mat| -> Mat {
        let (factors, _) = plnmf::serve::load_model(path).unwrap();
        let pool = Arc::new(ThreadPool::new(1));
        let p = Projector::with_spec(factors.w, pool, popts, spec).unwrap();
        p.project(Queries::Dense(q)).unwrap()
    };

    let mut rng = Pcg32::seeded(123);
    for round in 0..3 {
        let q = Mat::random(5, 30, &mut rng, 0.0, 1.0);
        let fro_ref = reference(&model_fro, EngineSpec::default(), &q);
        let kl_ref = reference(&model_kl, spec_kl, &q);

        for (name, want) in [("fro", &fro_ref), ("kl", &kl_ref)] {
            let (h_v1, res_v1, _) = v1.transform_dense(name, &q, true).unwrap();
            let (h_v2, res_v2, _) = v2.transform_dense(name, &q, true).unwrap();
            assert_eq!(h_v1, *want, "{name} round {round}: v1 h vs in-process reference");
            assert_eq!(h_v2, *want, "{name} round {round}: v2 h vs in-process reference");
            assert_eq!(res_v1, res_v2, "{name} round {round}: residuals across protocols");
            assert!(h_v1.data().iter().all(|&x| x >= 0.0 && x.is_finite()), "{name}");
        }
        // Different objectives genuinely produce different answers.
        assert_ne!(fro_ref, kl_ref, "round {round}");
    }

    // The stats op echoes each model's *effective* serving spec.
    let stats = v1.request_ok(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    let fro = stats.get("models").get("fro").get("spec");
    assert_eq!(fro.get("loss").as_str(), Some("frobenius"), "{stats}");
    assert_eq!(fro.get("alpha").as_f64(), Some(0.0));
    let kl = stats.get("models").get("kl").get("spec");
    assert_eq!(kl.get("loss").as_str(), Some("kl"), "{stats}");
    assert_eq!(kl.get("solver").as_str(), Some("mu"));
    assert_eq!(kl.get("alpha").as_f64(), Some(0.1));
    assert_eq!(kl.get("l1_ratio").as_f64(), Some(1.0));

    drop(v1);
    drop(v2);
    shutdown(addr);
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_serve_requires_a_model_source() {
    use plnmf::bench::cli_main;
    use plnmf::cli::Args;
    let args =
        Args::parse(["serve".to_string(), "--serve_port".to_string(), "0".to_string()]).unwrap();
    let err = format!("{:#}", cli_main(args).unwrap_err());
    assert!(err.contains("models_manifest") || err.contains("--model"), "{err}");
}

//! PJRT runtime integration: the rust coordinator executing the
//! AOT-compiled JAX/Pallas artifacts, checked against the native
//! engines. Requires `make artifacts` (the `test` set); tests skip with
//! a notice when artifacts are absent so `cargo test` stays runnable
//! standalone.

use std::sync::Arc;

use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::comparison::run_comparison;
use plnmf::nmf::NmfEngine;
use plnmf::parallel::ThreadPool;
use plnmf::runtime::engine::{MuXlaEngine, PlNmfXlaEngine};
use plnmf::runtime::Manifest;

fn artifacts_ready(names: &[&str]) -> bool {
    match Manifest::load(std::path::Path::new("artifacts")) {
        Ok(m) => {
            let ok = names.iter().all(|n| m.get(n).is_some());
            if !ok {
                eprintln!("SKIP: artifacts missing {names:?} — run `make artifacts`");
            }
            ok
        }
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            false
        }
    }
}

#[test]
fn manifest_entries_match_files_on_disk() {
    let Ok(m) = Manifest::load(std::path::Path::new("artifacts")) else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    assert!(!m.is_empty());
    for a in m.iter() {
        let path = m.hlo_path(a);
        assert!(path.exists(), "{} missing", path.display());
        let head = std::fs::read_to_string(&path).unwrap();
        assert!(head.starts_with("HloModule"), "{}", a.name);
        // Shape sanity vs profile dims.
        assert_eq!(a.inputs[0].shape.len(), 2);
        for spec in a.inputs.iter().chain(a.outputs.iter()) {
            assert!(spec.elements() > 0);
        }
    }
}

#[test]
fn xla_plnmf_matches_native_trajectory_dense() {
    if !artifacts_ready(&["plnmf_step__tiny_k8_t3"]) {
        return;
    }
    let mut cfg = RunConfig::default();
    cfg.dataset = "tiny".into();
    cfg.k = 8;
    cfg.max_iters = 10;
    cfg.threads = 2;
    let cmp = run_comparison(&cfg, &[EngineKind::PlNmf, EngineKind::PlNmfXla]).unwrap();
    assert_eq!(cmp.reports.len(), 2, "skipped: {:?}", cmp.skipped);
    for (a, b) in cmp.reports[0].trace.iter().zip(&cmp.reports[1].trace) {
        assert!(
            (a.rel_error - b.rel_error).abs() < 2e-3,
            "iter {}: native {} vs xla {}",
            a.iter,
            a.rel_error,
            b.rel_error
        );
    }
}

#[test]
fn xla_plnmf_matches_native_trajectory_sparse() {
    if !artifacts_ready(&["plnmf_update_h__tiny-sparse_k8_t3", "plnmf_update_w__tiny-sparse_k8_t3"])
    {
        return;
    }
    let mut cfg = RunConfig::default();
    cfg.dataset = "tiny-sparse".into();
    cfg.k = 8;
    cfg.max_iters = 8;
    cfg.threads = 2;
    let cmp = run_comparison(&cfg, &[EngineKind::PlNmf, EngineKind::PlNmfXla]).unwrap();
    assert_eq!(cmp.reports.len(), 2, "skipped: {:?}", cmp.skipped);
    for (a, b) in cmp.reports[0].trace.iter().zip(&cmp.reports[1].trace) {
        assert!(
            (a.rel_error - b.rel_error).abs() < 2e-3,
            "iter {}: native {} vs xla {}",
            a.iter,
            a.rel_error,
            b.rel_error
        );
    }
}

#[test]
fn xla_mu_matches_native_mu() {
    if !artifacts_ready(&["mu_step__tiny_k8_t3"]) {
        return;
    }
    let mut cfg = RunConfig::default();
    cfg.dataset = "tiny".into();
    cfg.k = 8;
    cfg.max_iters = 10;
    cfg.threads = 2;
    let cmp = run_comparison(&cfg, &[EngineKind::Mu, EngineKind::MuXla]).unwrap();
    assert_eq!(cmp.reports.len(), 2);
    for (a, b) in cmp.reports[0].trace.iter().zip(&cmp.reports[1].trace) {
        assert!(
            (a.rel_error - b.rel_error).abs() < 2e-3,
            "iter {}: {} vs {}",
            a.iter,
            a.rel_error,
            b.rel_error
        );
    }
}

#[test]
fn xla_engine_reports_device_timers() {
    if !artifacts_ready(&["plnmf_step__tiny_k8_t3"]) {
        return;
    }
    let ds = Arc::new(plnmf::data::load_dataset("tiny", 42).unwrap());
    let pool = Arc::new(ThreadPool::new(1));
    let mut e = PlNmfXlaEngine::new(ds, pool, 8, 42, "artifacts").unwrap();
    e.step().unwrap();
    assert_eq!(e.timers().count("xla_step"), 1);
    assert!(e.timers().count("h2d") >= 2);
    assert_eq!(e.tile, 3);
}

#[test]
fn missing_artifact_is_a_clear_error() {
    let ds = Arc::new(plnmf::data::load_dataset("tiny", 42).unwrap());
    let pool = Arc::new(ThreadPool::new(1));
    let err = match MuXlaEngine::new(ds, pool, 999, 42, "artifacts") {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("k=999 must not have an artifact"),
    };
    assert!(
        err.contains("make artifacts") || err.contains("no artifact") || err.contains("aot"),
        "unhelpful error: {err}"
    );
}

#!/usr/bin/env python3
"""Generate rust/tests/golden/traces.json without a Rust toolchain.

The golden-trace regression (rust/tests/golden_traces.rs) snapshots the
rel-error trajectory of every native CPU engine on the two `tiny`
profiles and hard-fails on CI while the snapshot is missing. The
snapshot is normally bootstrapped by `cargo test`; this script produces
the same trajectories from a numpy port so the snapshot can be
generated (and audited) in a container that has Python but no cargo.

Fidelity contract, matching the Rust test's tolerance model
(|got - want| <= 2e-3 * max(1, |want|) per trace point, which absorbs
floating-point reassociation but not algorithmic drift):

* The RNG (PCG32), the synthetic dataset generators, and the factor
  initialization are transliterated exactly — integer-for-integer and
  (for the f32 casts) rounding-for-rounding — so the *inputs* to every
  engine are bit-identical to the Rust run.
* The engine updates run in the same precision regime (f32 storage and
  elementwise arithmetic, f64 for norm/objective accumulations); the
  only differences vs. Rust are summation order inside matrix products
  — exactly the reassociation slack the tolerance exists for.

Self-checks at the bottom assert the structural invariants the Rust
test asserts (finite, 11 points, error decreases) plus dataset facts
(exact nnz, unit-norm W columns) so a transliteration slip fails here
rather than on CI.

Usage:  python3 python/tools/gen_golden_traces.py [out.json]
"""

import json
import math
import sys
from pathlib import Path

import numpy as np

F32 = np.float32
MASK64 = (1 << 64) - 1
EPS = F32(1e-16)  # crate::EPS
DELTA = F32(1e-9)  # MU / MU-KL denominator guard
RIDGE = 1e-10  # BPP Cholesky ridge
MAX_EXCHANGES = 200

# ---------------------------------------------------------------------------
# util/rng.rs — PCG-XSH-RR 64/32, exact.
# ---------------------------------------------------------------------------


class Pcg32:
    MULT = 6364136223846793005

    def __init__(self, seed: int, stream: int) -> None:
        self.state = 0
        self.inc = ((stream << 1) | 1) & MASK64
        self.next_u32()
        self.state = (self.state + seed) & MASK64
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * self.MULT + self.inc) & MASK64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & 0xFFFFFFFF

    def next_u64(self) -> int:
        return (self.next_u32() << 32) | self.next_u32()

    def next_f32(self) -> np.float32:
        # (u32 >> 8) as f32 * (1 / 2^24): both factors and the product
        # are exact in f32.
        return F32(self.next_u32() >> 8) * F32(1.0 / (1 << 24))

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, bound: int) -> int:
        # Lemire multiply-shift rejection, exact integer semantics.
        threshold = ((1 << 32) - bound) % bound
        while True:
            x = self.next_u32()
            m = x * bound
            low = m & 0xFFFFFFFF
            if low >= bound or low >= threshold:
                return m >> 32

    def range_f32(self, lo: float, hi: float) -> np.float32:
        return F32(lo) + (F32(hi) - F32(lo)) * self.next_f32()

    def next_gaussian(self) -> float:
        while True:
            u = self.next_f64()
            if u > 1e-12:
                v = self.next_f64()
                return math.sqrt(-2.0 * math.log(u)) * math.cos(2.0 * math.pi * v)

    def next_lognormal(self, mu: float, sigma: float) -> float:
        return math.exp(mu + sigma * self.next_gaussian())

    def split(self, stream: int) -> "Pcg32":
        seed = (self.next_u32() << 32) | self.next_u32()
        return Pcg32(seed, (stream * 2654435761 + 1) & MASK64)


def mat_random(rows: int, cols: int, rng: Pcg32, lo: float, hi: float) -> np.ndarray:
    out = np.empty((rows, cols), F32)
    flat = out.reshape(-1)
    for i in range(rows * cols):  # row-major fill order, like Mat::random
        flat[i] = rng.range_f32(lo, hi)
    return out


# ---------------------------------------------------------------------------
# data/text.rs — Zipf bag-of-words corpus, exact (returned dense).
# ---------------------------------------------------------------------------


def doc_lengths(d: int, nnz: int, v: int, rng: Pcg32) -> list:
    raw = [rng.next_lognormal(0.0, 0.6) for _ in range(d)]
    scale = nnz / sum(raw)  # sequential f64 sum, same order as Rust
    lens, fracs, total = [], [], 0
    for i, x in enumerate(raw):
        t = min(max(x * scale, 1.0), float(v))
        fl = int(math.floor(t))
        lens.append(fl)
        total += fl
        fracs.append((t - fl, i))
    if total < nnz:
        need = nnz - total
        # Stable descending sort on the fractional part (total_cmp).
        fracs.sort(key=lambda p: -p[0])
        cursor = 0
        while need > 0:
            _, i = fracs[cursor % len(fracs)]
            if lens[i] < v:
                lens[i] += 1
                need -= 1
            cursor += 1
            assert cursor < 100 * len(fracs) + 100
    elif total > nnz:
        excess = total - nnz
        cursor = 0
        while excess > 0:
            i = cursor % d
            if lens[i] > 1:
                lens[i] -= 1
                excess -= 1
            cursor += 1
    assert sum(lens) == nnz
    return lens


def zipf_cdf(v: int, s: float) -> list:
    cdf, acc = [], 0.0
    for r in range(1, v + 1):
        acc += 1.0 / math.pow(float(r), s)
        cdf.append(acc)
    return [x / acc for x in cdf]


def zipf_sample(cdf: list, rng: Pcg32) -> int:
    import bisect

    u = rng.next_f64()
    i = bisect.bisect_left(cdf, u)  # == binary_search_by insertion point
    return min(i, len(cdf) - 1)


def generate_corpus(v: int, d: int, nnz: int, s: float, seed: int) -> np.ndarray:
    rng = Pcg32(seed, 1001)
    lens = doc_lengths(d, nnz, v, rng)
    cdf = zipf_cdf(v, s)
    a = np.zeros((v, d), F32)
    placed = 0
    for doc, ln in enumerate(lens):
        drng = Pcg32(seed ^ 0x9E3779B97F4A7C15, 2_000_000 + doc)
        counts = {}
        guard = 0
        while len(counts) < ln:
            w = zipf_sample(cdf, drng)
            counts[w] = counts.get(w, 0) + 1
            guard += 1
            if guard > 50 * ln + 1000:
                w = drng.below(v)
                while w in counts:
                    w = (w + 1) % v
                counts[w] = 1
        for w, c in counts.items():
            a[w, doc] = F32(1.0) + F32(np.log(F32(c)))  # f32 ln, like Rust
            placed += 1
    assert placed == nnz, f"corpus nnz {placed} != {nnz}"
    return a


# ---------------------------------------------------------------------------
# data/image.rs — planted low-rank dense images, exact.
# ---------------------------------------------------------------------------


def generate_images(v: int, d: int, r: int, seed: int) -> np.ndarray:
    rng = Pcg32(seed, 3001)
    basis = np.zeros((r, d), F32)
    j64 = np.arange(d, dtype=np.float64)
    for k in range(r):
        brng = rng.split(10 + k)
        for _ in range(3):
            center = brng.next_f64() * d
            width = (0.02 + 0.08 * brng.next_f64()) * d
            height = 0.3 + brng.next_f64()
            z = (j64 - center) / width
            bump = (height * np.exp(-0.5 * z * z)).astype(F32)  # f64 math, f32 cast
            basis[k] = basis[k] + bump  # f32 add, element order per j
    coeff = np.empty((v, r), F32)
    cflat = coeff.reshape(-1)
    for i in range(v * r):  # row-major, like the Rust double loop
        u = rng.next_f32()
        cflat[i] = u * u
    a = np.zeros((v, d), F32)
    for i in range(v):
        for k in range(r):
            c = coeff[i, k]
            if c != 0.0:
                a[i] = a[i] + c * basis[k]  # f32 FMA-free: mul then add
    mx = max(F32(np.max(a)) if a.size else F32(0.0), F32(1e-6))
    inv = F32(240.0) / mx
    nrng = rng.split(99)
    noise = np.empty(v * d, F32)
    for i in range(v * d):  # row-major data order
        noise[i] = nrng.next_f32()
    a = (a.reshape(-1) * inv + F32(12.0) * noise).reshape(v, d)
    return a


# ---------------------------------------------------------------------------
# nmf/init.rs — shared random init, exact.
# ---------------------------------------------------------------------------


def factors_random(v: int, d: int, k: int, seed: int):
    rng = Pcg32(seed, 77)
    w = mat_random(v, k, rng, 0.0, 1.0)
    h = mat_random(d, k, rng, 0.0, 1.0)
    # normalize_w_columns: f64 norms accumulated row by row, f32 scale.
    norms = np.zeros(k, np.float64)
    w64 = w.astype(np.float64)
    for i in range(v):
        norms += w64[i] * w64[i]
    inv = np.empty(k, F32)
    for j in range(k):
        inv[j] = F32(1.0) / F32(max(math.sqrt(norms[j]), 1e-30))
    w *= inv
    return w, h


# ---------------------------------------------------------------------------
# Engine updates (f32 regime; products f64-accumulated then stored f32,
# reassociation-level equivalent to the Rust kernels).
# ---------------------------------------------------------------------------


def gram(x: np.ndarray) -> np.ndarray:
    x64 = x.astype(np.float64)
    return (x64.T @ x64).astype(F32)


def matmul_f32(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return (a.astype(np.float64) @ b.astype(np.float64)).astype(F32)


def hals_update(x: np.ndarray, g: np.ndarray, b: np.ndarray, normalize: bool) -> None:
    """halsops::update_reference semantics: sequential columns over the
    mixed state, EPS clamp, optional f64-norm / f32-scale."""
    k = x.shape[1]
    for t in range(k):
        s = x @ g[:, t]  # f32 accumulation (reassociation-level only)
        if normalize:
            v = x[:, t] * g[t, t] + b[:, t] - s
        else:
            v = x[:, t] + b[:, t] - s
        v = np.where(v < EPS, EPS, v).astype(F32)
        if normalize:
            total = float(np.sum(v.astype(np.float64) ** 2))
            inv = 1.0 / math.sqrt(total) if total > 0.0 else 1.0
            v = v * F32(inv)
        x[:, t] = v


def step_hals(a, at, w, h):
    r = matmul_f32(at, w)
    s = gram(w)
    hals_update(h, s, r, normalize=False)
    p = matmul_f32(a, h)
    q = gram(h)
    hals_update(w, q, p, normalize=True)


def mu_update(x: np.ndarray, g: np.ndarray, num: np.ndarray) -> np.ndarray:
    denom = (x @ g) + DELTA  # pre-update rows, f32
    return (x * (num / denom)).astype(F32)


def step_mu(a, at, w, h):
    r = matmul_f32(at, w)
    s = gram(w)
    h2 = mu_update(h, s, r)
    p = matmul_f32(a, h2)
    q = gram(h2)
    w2 = mu_update(w, q, p)
    return w2, h2


def kl_half_step(
    a: np.ndarray, x: np.ndarray, other: np.ndarray, l1=F32(0.0), l2=F32(0.0)
) -> np.ndarray:
    """mukl::kl_half_step: x ← x ⊙ (ratio·other) ⊘ (colsum(other) + l1 +
    l2·x), with the ratio a/(x·otherᵀ+δ) taken over A's support only.
    Zero shrink is the identical free path (adding f32 0.0 is exact)."""
    denom = np.zeros(other.shape[1], np.float64)
    for i in range(other.shape[0]):  # row-order f64 column sums
        denom += other[i].astype(np.float64)
    wh = (x @ other.T) + DELTA  # f32
    ratio = np.where(a != 0.0, a / wh, F32(0.0)).astype(F32)
    num = matmul_f32(ratio, other)
    d = denom.astype(F32) + DELTA + l1 + l2 * x  # f32, Rust's add order
    return (x * (num / d)).astype(F32)


def step_mukl(a, at, w, h, l1=F32(0.0), l2=F32(0.0)):
    h2 = kl_half_step(at, h, w, l1, l2)  # only H carries the penalty
    w2 = kl_half_step(a, w, h2)
    return w2, h2


def cholesky_solve(a: np.ndarray, b: np.ndarray, p: int) -> bool:
    """In-place lower Cholesky + solve, exact transliteration (the
    `s <= 0 -> not SPD` decision included)."""
    for i in range(p):
        for j in range(i + 1):
            s = a[i, j]
            for t in range(j):
                s -= a[i, t] * a[j, t]
            if i == j:
                if s <= 0.0:
                    return False
                a[i, i] = math.sqrt(s)
            else:
                a[i, j] = s / a[j, j]
    for i in range(p):
        s = b[i]
        for t in range(i):
            s -= a[i, t] * b[t]
        b[i] = s / a[i, i]
    for i in range(p - 1, -1, -1):
        s = b[i]
        for t in range(i + 1, p):
            s -= a[t, i] * b[t]
        b[i] = s / a[i, i]
    return True


def nnls_bpp_row(g64: np.ndarray, b_row: np.ndarray) -> np.ndarray:
    k = g64.shape[0]
    passive = [True] * k
    x = np.zeros(k, np.float64)
    best_infeasible = 1 << 62
    backup_budget = 3
    for _ in range(MAX_EXCHANGES):
        idx = [j for j in range(k) if passive[j]]
        p = len(idx)
        x[:] = 0.0
        if p > 0:
            chol = np.empty((p, p), np.float64)
            rhs = np.empty(p, np.float64)
            for pi, gi in enumerate(idx):
                for pj, gj in enumerate(idx):
                    chol[pi, pj] = g64[gi, gj]
                chol[pi, pi] += RIDGE
                rhs[pi] = float(b_row[gi])
            if not cholesky_solve(chol, rhs, p):
                break
            for pi, gi in enumerate(idx):
                x[gi] = rhs[pi]
        y = np.zeros(k, np.float64)
        for j in range(k):
            if not passive[j]:
                s = -float(b_row[j])
                for gi in idx:
                    s += g64[j, gi] * x[gi]
                y[j] = s
        v1 = None
        count = 0
        for j in range(k):
            infeasible = (passive[j] and x[j] < 0.0) or (not passive[j] and y[j] < 0.0)
            if infeasible:
                count += 1
                v1 = j
        if count == 0:
            break
        if count < best_infeasible:
            best_infeasible = count
            backup_budget = 3
            full = True
        elif backup_budget > 0:
            backup_budget -= 1
            full = True
        else:
            full = False
        if full:
            for j in range(k):
                if passive[j] and x[j] < 0.0:
                    passive[j] = False
                elif not passive[j] and y[j] < 0.0:
                    passive[j] = True
        else:
            passive[v1] = not passive[v1]
    return np.maximum(x, 0.0).astype(F32)


def nnls_bpp_rows(g: np.ndarray, b: np.ndarray) -> np.ndarray:
    g64 = g.astype(np.float64)
    return np.stack([nnls_bpp_row(g64, b[i]) for i in range(b.shape[0])])


def step_bpp(a, at, w, h):
    r = matmul_f32(at, w)
    s = gram(w)
    h2 = nnls_bpp_rows(s, r)
    p = matmul_f32(a, h2)
    q = gram(h2)
    w2 = nnls_bpp_rows(q, p)
    return w2, h2


# ---------------------------------------------------------------------------
# nmf/error.rs — relative objective via the Gram trick.
# ---------------------------------------------------------------------------


def rel_error(a: np.ndarray, fro2: float, w: np.ndarray, h: np.ndarray) -> float:
    p = matmul_f32(a, h)
    q = gram(h)
    s = gram(w)
    pw = float(np.sum(p.astype(np.float64) * w.astype(np.float64)))
    qs = float(np.sum(q.astype(np.float64) * s.astype(np.float64)))
    num = max(fro2 - 2.0 * pw + qs, 0.0)
    return math.sqrt(num / fro2)


# ---------------------------------------------------------------------------
# The golden_traces.rs job: 5 engines × 2 datasets × 10 iterations.
# ---------------------------------------------------------------------------

ITERS = 10
K = 4
SEED = 7  # both the dataset seed and the factor-init seed


def run_engine(engine: str, a: np.ndarray, alpha: float = 0.0, l1_ratio: float = 0.0) -> list:
    v, d = a.shape
    at = np.ascontiguousarray(a.T)
    fro2 = float(np.sum(a.astype(np.float64) ** 2))
    w, h = factors_random(v, d, K, SEED)
    # EngineSpec::shrink(): l1 = (α·ρ) as f32, l2 = (α·(1−ρ)) as f32.
    l1 = F32(alpha * l1_ratio)
    l2 = F32(alpha * (1.0 - l1_ratio))
    trace = [rel_error(a, fro2, w, h)]
    for _ in range(ITERS):
        if engine in ("plnmf", "fasthals"):
            step_hals(a, at, w, h)  # in-place
        elif engine == "mu":
            w, h = step_mu(a, at, w, h)
        elif engine == "mukl":
            w, h = step_mukl(a, at, w, h, l1, l2)
        elif engine == "bpp":
            w, h = step_bpp(a, at, w, h)
        else:
            raise ValueError(engine)
        trace.append(rel_error(a, fro2, w, h))
    return trace


def main() -> None:
    repo = Path(__file__).resolve().parents[2]
    out = Path(sys.argv[1]) if len(sys.argv) > 1 else repo / "rust/tests/golden/traces.json"

    datasets = {
        # config/profiles.rs: the two unit-test profiles, at SEED.
        "tiny": generate_images(60, 40, 6, SEED),
        "tiny-sparse": generate_corpus(80, 50, 400, 1.1, SEED),
    }
    # Dataset self-checks (mirrors rust/src/data tests).
    assert int(np.count_nonzero(datasets["tiny-sparse"])) == 400
    assert (datasets["tiny-sparse"].sum(axis=0) > 0).all(), "empty document"
    assert float(np.max(datasets["tiny"])) <= 256.0
    w_chk, _ = factors_random(60, 40, K, SEED)
    col_norms = np.sum(w_chk.astype(np.float64) ** 2, axis=0)
    assert np.allclose(col_norms, 1.0, atol=1e-5), col_norms

    traces = {}
    for dataset, a in datasets.items():
        for engine in ["plnmf", "fasthals", "mu", "mukl", "bpp"]:
            trace = run_engine(engine, a.copy())
            key = f"{engine}/{dataset}"
            # The structural assertions golden_traces.rs makes.
            assert len(trace) == ITERS + 1, key
            assert all(math.isfinite(e) for e in trace), (key, trace)
            assert trace[ITERS] <= trace[0], (key, trace)
            traces[key] = trace
            print(f"{key:>20}: {trace[0]:.4f} -> {trace[-1]:.4f}")

    # The one regularized golden job: elastic-net KL (alpha=0.1,
    # l1_ratio=0.5 — the EngineSpec surface) on the sparse corpus. Pins
    # the H-denominator penalty terms so they cannot silently drift.
    trace = run_engine("mukl", datasets["tiny-sparse"].copy(), alpha=0.1, l1_ratio=0.5)
    key = "mukl+reg/tiny-sparse"
    assert len(trace) == ITERS + 1, key
    assert all(math.isfinite(e) for e in trace), (key, trace)
    assert trace[ITERS] <= trace[0], (key, trace)
    # The penalty must actually change the trajectory vs. the free run.
    assert trace[ITERS] != traces["mukl/tiny-sparse"][ITERS], key
    traces[key] = trace
    print(f"{key:>20}: {trace[0]:.4f} -> {trace[-1]:.4f}")

    # Cross-engine sanity: exact subproblem solves (BPP) should be at
    # least as good per-iteration as HALS, and HALS at least as good as
    # MU (the Fig. 8 qualitative ordering), loosely checked.
    for dataset in datasets:
        hals = traces[f"fasthals/{dataset}"][-1]
        mu = traces[f"mu/{dataset}"][-1]
        bpp = traces[f"bpp/{dataset}"][-1]
        assert hals <= mu + 1e-3, (dataset, hals, mu)
        assert bpp <= hals * 1.1 + 1e-3, (dataset, bpp, hals)

    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(traces, indent=2, sort_keys=True) + "\n")
    print(f"wrote {out} ({len(traces)} traces)")


if __name__ == "__main__":
    main()

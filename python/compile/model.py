"""Layer-2 JAX model: the PL-NMF update graphs (and MU baseline) that get
AOT-lowered to HLO text and executed by the rust runtime.

Everything here composes the Layer-1 Pallas kernels:

* ``plnmf_update_w`` / ``plnmf_update_h`` — the tiled three-phase updates
  (Alg. 2) given precomputed products. These are the artifacts the rust
  coordinator calls for *sparse* datasets, where it computes
  ``P = A Ht`` / ``R = A^T W`` itself with the CSR SpMM (XLA has no
  sparse kernels; the paper's GPU code used cusparseDcsrmm for the same
  step — see DESIGN.md §5).
* ``plnmf_step_dense`` — a full outer iteration on a device-resident
  dense A (the att/pie path): products + both tiled updates fused into
  one executable, so per-iteration host traffic is zero.
* ``mu_step_dense`` / ``mu_update_*`` — the MU baseline through the same
  lowering pipeline (the bionmf-MU-gpu stand-in).

The tile width T is a static Python int: tiles are unrolled at trace
time, so each artifact is specialized to (V, D, K, T) — exactly like the
paper's implementation is re-tuned per dataset/K.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import panel_gemm as pg
from .kernels import phase2 as p2

EPS = 1e-16


def _tiles(k, t):
    """[(t0, t1), ...] covering 0..k in panels of width t."""
    out = []
    t0 = 0
    while t0 < k:
        out.append((t0, min(t0 + t, k)))
        t0 += t
    return out


# ---------------------------------------------------------------------------
# Tiled updates (Alg. 2).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("tile", "eps"))
def plnmf_update_w(w, q, p, tile, eps=EPS):
    """Tiled W update: init + phase 1 GEMMs + per-tile (phase 2, phase 3).

    w: (V, K) pre-update W; q: (K, K); p: (V, K). Returns the updated,
    column-normalized W.
    """
    k = w.shape[1]
    spans = _tiles(k, tile)
    w_old = w
    # init: W_new = W_old * diag(Q)  (Alg. 2 lines 3-8)
    w_new = w_old * jnp.diag(q)[None, :]
    # phase 1: old panels contribute to all columns on their left.
    for (t0, t1) in spans[1:]:
        left = pg.panel_gemm(w_old[:, t0:t1], q[t0:t1, :t0], w_new[:, :t0], alpha=-1.0)
        w_new = jnp.concatenate([left, w_new[:, t0:]], axis=1)
    # per tile: phase 2 (sequential in-tile columns + norm), phase 3.
    for (t0, t1) in spans:
        tile_new = p2.phase2_tile_w(
            w_new[:, t0:t1], w_old[:, t0:t1], q[t0:t1, t0:t1], p[:, t0:t1], eps=eps
        )
        w_new = jnp.concatenate([w_new[:, :t0], tile_new, w_new[:, t1:]], axis=1)
        if t1 < k:
            right = pg.panel_gemm(w_new[:, t0:t1], q[t0:t1, t1:], w_new[:, t1:], alpha=-1.0)
            w_new = jnp.concatenate([w_new[:, :t1], right], axis=1)
    return w_new


@functools.partial(jax.jit, static_argnames=("tile", "eps"))
def plnmf_update_h(h, s, r, tile, eps=EPS):
    """Tiled H update: same three phases, identity diagonal, no norm."""
    k = h.shape[1]
    spans = _tiles(k, tile)
    h_old = h
    h_new = h  # identity init: the `+H_t` term of Alg. 1 line 7
    for (t0, t1) in spans[1:]:
        left = pg.panel_gemm(h_old[:, t0:t1], s[t0:t1, :t0], h_new[:, :t0], alpha=-1.0)
        h_new = jnp.concatenate([left, h_new[:, t0:]], axis=1)
    for (t0, t1) in spans:
        tile_new = p2.phase2_tile_h(
            h_new[:, t0:t1], h_old[:, t0:t1], s[t0:t1, t0:t1], r[:, t0:t1], eps=eps
        )
        h_new = jnp.concatenate([h_new[:, :t0], tile_new, h_new[:, t1:]], axis=1)
        if t1 < k:
            right = pg.panel_gemm(h_new[:, t0:t1], s[t0:t1, t1:], h_new[:, t1:], alpha=-1.0)
            h_new = jnp.concatenate([h_new[:, :t1], right], axis=1)
    return h_new


# ---------------------------------------------------------------------------
# Full steps (artifact entry points).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("tile", "eps"))
def plnmf_step_dense(a, w, h, tile, eps=EPS):
    """One full PL-NMF outer iteration on dense A: returns (w', h')."""
    r = a.T @ w
    s = w.T @ w
    h = plnmf_update_h(h, s, r, tile, eps=eps)
    p = a @ h
    q = h.T @ h
    w = plnmf_update_w(w, q, p, tile, eps=eps)
    return w, h


@functools.partial(jax.jit, static_argnames=("tile", "eps"))
def plnmf_update_h_from_r(w, h, r, tile, eps=EPS):
    """Sparse-path half step: S computed on device, R supplied by rust."""
    s = w.T @ w
    return plnmf_update_h(h, s, r, tile, eps=eps)


@functools.partial(jax.jit, static_argnames=("tile", "eps"))
def plnmf_update_w_from_p(w, h, p, tile, eps=EPS):
    """Sparse-path half step: Q computed on device, P supplied by rust."""
    q = h.T @ h
    return plnmf_update_w(w, q, p, tile, eps=eps)


@jax.jit
def mu_step_dense(a, w, h):
    """MU baseline, dense A (bionmf-MU-gpu stand-in)."""
    delta = 1e-9
    r = a.T @ w
    s = w.T @ w
    h = h * r / (h @ s + delta)
    p = a @ h
    q = h.T @ h
    w = w * p / (w @ q + delta)
    return w, h


@jax.jit
def mu_update_h_from_r(w, h, r):
    delta = 1e-9
    s = w.T @ w
    return h * r / (h @ s + delta)


@jax.jit
def mu_update_w_from_p(w, h, p):
    delta = 1e-9
    q = h.T @ h
    return w * p / (w @ q + delta)


@jax.jit
def rel_error_dense(a, w, h):
    """Relative objective via the Gram trick (no V x D materialization)."""
    p = a @ h
    q = h.T @ h
    s = w.T @ w
    a2 = jnp.sum(a * a)
    num = jnp.maximum(a2 - 2.0 * jnp.sum(p * w) + jnp.sum(q * s), 0.0)
    return jnp.sqrt(num / a2)

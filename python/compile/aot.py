"""AOT lowering: JAX/Pallas update graphs -> HLO text + manifest.json.

Run once by `make artifacts`; python never runs on the request path. The
interchange format is HLO *text*, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact naming: ``{fn}__{dataset}_k{K}_t{T}.hlo.txt`` with a manifest
entry carrying shapes/dtypes so the rust runtime
(rust/src/runtime/manifest.rs) can validate inputs before compile.

Tile selection mirrors rust/src/nmf/cost_model.rs::select_tile —
round(sqrt(K - 2/sqrt(C))) with C = 35 MiB of doubles — so the two layers
agree on T for a given K without coordination.
"""

import argparse
import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, V, D, sparse?) — mirrors rust/src/config/profiles.rs. NNZ and
# generator params live on the rust side only; artifacts depend on shapes.
PROFILES = {
    "tiny": (60, 40, False),
    "tiny-sparse": (80, 50, True),
    "20news-small": (3277, 1414, True),
    "tdt2-small": (4596, 1276, True),
    "reuters-small": (2366, 1036, True),
    "att-small": (100, 1288, False),
    "pie-small": (1444, 512, False),
    "20news": (26214, 11314, True),
    "tdt2": (36771, 10212, True),
    "reuters": (18933, 8293, True),
    "att": (400, 10304, False),
    "pie": (11554, 4096, False),
}

CACHE_WORDS = 35 * 1024 * 1024 / 8  # the paper's 35 MB LLC, in doubles


def select_tile(k: int) -> int:
    """Eq. 11, rounded — must match rust's cost_model::select_tile."""
    t = round(math.sqrt(max(k - 2.0 / math.sqrt(CACHE_WORDS), 1.0)))
    return max(1, min(t, k))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_plan(dataset: str, k: int):
    """The artifacts one (dataset, k) config needs, as
    (fn_name, callable, example_args, static_kwargs)."""
    v, d, sparse = PROFILES[dataset]
    t = select_tile(k)
    w, h = f32(v, k), f32(d, k)
    plan = []
    if sparse:
        # Half-step artifacts: rust supplies R = A^T W and P = A Ht via
        # its CSR SpMM (XLA has no sparse kernels; DESIGN.md §5).
        plan.append(("plnmf_update_h", model.plnmf_update_h_from_r, (w, h, f32(d, k)), {"tile": t}))
        plan.append(("plnmf_update_w", model.plnmf_update_w_from_p, (w, h, f32(v, k)), {"tile": t}))
        plan.append(("mu_update_h", model.mu_update_h_from_r, (w, h, f32(d, k)), {}))
        plan.append(("mu_update_w", model.mu_update_w_from_p, (w, h, f32(v, k)), {}))
    else:
        a = f32(v, d)
        plan.append(("plnmf_step", model.plnmf_step_dense, (a, w, h), {"tile": t}))
        plan.append(("mu_step", model.mu_step_dense, (a, w, h), {}))
        plan.append(("rel_error", model.rel_error_dense, (a, w, h), {}))
    return t, plan


# Default build sets. `test` covers everything the test-suite and the
# quickstart need; `paper` adds the five Table-4 configs at the Fig. 9
# operating point (K = 240). `all` additionally sweeps K = 80/160.
SETS = {
    "test": [("tiny", 8), ("tiny-sparse", 8), ("20news-small", 32), ("tdt2-small", 32),
             ("reuters-small", 32), ("att-small", 32), ("pie-small", 32)],
    "paper": [(name, 240) for name in ["20news", "tdt2", "reuters", "att", "pie"]],
    "sweep": [(name, k) for name in ["20news", "tdt2", "reuters", "att", "pie"]
              for k in (80, 160)],
}


def build(out_dir: str, configs, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"version": 1, "artifacts": []}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    existing = {a["name"] for a in manifest["artifacts"]}

    for dataset, k in configs:
        v, d, sparse = PROFILES[dataset]
        t, plan = artifact_plan(dataset, k)
        for fn_name, fn, args, kwargs in plan:
            name = f"{fn_name}__{dataset}_k{k}_t{t}"
            fname = f"{name}.hlo.txt"
            fpath = os.path.join(out_dir, fname)
            if name in existing and os.path.exists(fpath):
                if verbose:
                    print(f"  cached  {name}")
                continue
            t0 = time.time()
            lowered = jax.jit(fn, static_argnames=tuple(kwargs)).lower(*args, **kwargs)
            text = to_hlo_text(lowered)
            with open(fpath, "w") as f:
                f.write(text)
            out_shapes = [list(s.shape) for s in jax.tree_util.tree_leaves(lowered.out_info)]
            entry = {
                "name": name,
                "file": fname,
                "fn": fn_name,
                "dataset": dataset,
                "v": v,
                "d": d,
                "k": k,
                "tile": t,
                "sparse": sparse,
                "inputs": [{"shape": list(a.shape), "dtype": "f32"} for a in args],
                "outputs": [{"shape": s, "dtype": "f32"} for s in out_shapes],
            }
            manifest["artifacts"] = [a for a in manifest["artifacts"] if a["name"] != name]
            manifest["artifacts"].append(entry)
            existing.add(name)
            if verbose:
                print(f"  lowered {name}  ({len(text) / 1e6:.1f} MB HLO, {time.time() - t0:.1f}s)")
        # Write the manifest incrementally so partial builds stay usable.
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sets", default="test,paper",
                    help="comma list of build sets: test, paper, sweep")
    ap.add_argument("--config", action="append", default=[],
                    help="extra dataset:K pairs, e.g. --config pie:160")
    args = ap.parse_args()

    configs = []
    for s in args.sets.split(","):
        s = s.strip()
        if s:
            configs.extend(SETS[s])
    for c in args.config:
        name, k = c.split(":")
        configs.append((name, int(k)))

    print(f"AOT-lowering {len(configs)} configs -> {args.out_dir}")
    manifest = build(args.out_dir, configs)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Pure-jnp FAST-HALS oracle (Algorithm 1, transliterated).

This is the correctness anchor for the whole stack: the Pallas kernels
(`panel_gemm.py`, `phase2.py`), the L2 tiled model (`model.py`), and — via
the shared convergence-trajectory tests — the rust engines are all checked
against these functions.

Storage convention matches the rust side: ``W`` is (V, K); ``H`` is stored
transposed as (D, K). ``A`` is (V, D) dense (the oracle is dense-only; the
sparse path exercises the same update functions with precomputed
products).
"""

import jax.numpy as jnp

EPS = 1e-16


def hals_update_h(h, s, r, eps=EPS):
    """Alg. 1 lines 4-8: sequential row updates of H.

    h: (D, K) current H (transposed storage), updated feature-by-feature.
    s: (K, K) Gram S = W^T W.
    r: (D, K) R = A^T W.
    """
    k = h.shape[1]
    for t in range(k):
        # sum_j h[:, j] * s[j, t] with the current mixed h (cols < t new).
        coupled = h @ s[:, t]
        new_col = jnp.maximum(eps, h[:, t] + r[:, t] - coupled)
        h = h.at[:, t].set(new_col)
    return h


def hals_update_w(w, q, p, eps=EPS):
    """Alg. 1 lines 10-16: sequential column updates of W + L2 norm.

    w: (V, K); q: (K, K) Gram Q = H H^T; p: (V, K) P = A H^T.
    """
    k = w.shape[1]
    for t in range(k):
        coupled = w @ q[:, t]
        new_col = jnp.maximum(eps, w[:, t] * q[t, t] + p[:, t] - coupled)
        norm = jnp.sqrt(jnp.sum(new_col * new_col))
        new_col = new_col / jnp.where(norm > 0.0, norm, 1.0)
        w = w.at[:, t].set(new_col)
    return w


def fast_hals_step(a, w, h, eps=EPS):
    """One full FAST-HALS outer iteration on dense A."""
    r = a.T @ w
    s = w.T @ w
    h = hals_update_h(h, s, r, eps)
    p = a @ h
    q = h.T @ h
    w = hals_update_w(w, q, p, eps)
    return w, h


def mu_step(a, w, h, delta=1e-9):
    """Multiplicative updates (Lee-Seung), matching rust/src/nmf/mu.rs."""
    r = a.T @ w
    s = w.T @ w
    h = h * r / (h @ s + delta)
    p = a @ h
    q = h.T @ h
    w = w * p / (w @ q + delta)
    return w, h


def rel_error(a, w, h):
    """Kim & Park relative objective; materializes WH (oracle only)."""
    diff = a - w @ h.T
    return jnp.sqrt(jnp.sum(diff * diff) / jnp.sum(a * a))

"""Layer-1 Pallas kernels for PL-NMF phase 2 (the in-tile sequential
column updates, Alg. 2 lines 15-38 / GPU Algs. 4-5).

Two realizations, both interpret=True (see panel_gemm.py):

* ``phase2_tile_w`` / ``phase2_tile_h`` — one program owns the whole
  V x T (resp. D x T) tile slab and runs the T-step sequential loop in
  VMEM. This is the shape the AOT model uses: the slab is the tile's
  entire working set (V*T*4 B ~ 1.5 MiB at V=26214, T=15 — VMEM-resident,
  which is exactly the locality the paper engineers via its L2-cache
  tiling). The H variant is additionally blocked over rows since without
  the interleaved normalization every row is independent.

* ``phase2_col`` + ``norm_scale`` — the faithful port of the paper's GPU
  kernels (Alg. 4: one kernel launch per column with hierarchical
  reduction; Alg. 5: the norm kernel). The V dimension is blocked across
  the grid; each program emits its partial sum of squares (the TPU
  analogue of warp-shuffle + atomicAdd is per-block partials + a
  deterministic jnp.sum at Layer 2 — TPUs have no global atomics). Used
  by the test suite to pin the two realizations against each other and
  against ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-16


# ---------------------------------------------------------------------------
# Whole-tile kernels (used by the AOT model).
# ---------------------------------------------------------------------------


def _phase2_tile_w_kernel(wt_ref, wo_ref, q_ref, p_ref, o_ref, *, t_width, eps):
    wt = wt_ref[...]
    wo = wo_ref[...]
    q = q_ref[...]
    p = p_ref[...]
    for t in range(t_width):  # static unroll: T is a compile-time tile width
        s_new = wt[:, :t] @ q[:t, t] if t > 0 else 0.0
        s_old = wo[:, t:] @ q[t:, t]
        col = jnp.maximum(eps, wt[:, t] + p[:, t] - s_new - s_old)
        norm2 = jnp.sum(col * col)
        inv = jnp.where(norm2 > 0.0, jax.lax.rsqrt(norm2), 1.0)
        wt = wt.at[:, t].set(col * inv)
    o_ref[...] = wt


@functools.partial(jax.jit, static_argnames=("eps",))
def phase2_tile_w(w_tile, wold_tile, q_tile, p_tile, eps=EPS):
    """W-flavor phase 2 over one tile: sequential columns + L2 norm.

    w_tile: (V, T) the W_new slab (after init and phase 1);
    wold_tile: (V, T) pre-update values; q_tile: (T, T); p_tile: (V, T).
    """
    v, t_width = w_tile.shape
    return pl.pallas_call(
        functools.partial(_phase2_tile_w_kernel, t_width=t_width, eps=eps),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((v, t_width), lambda i: (0, 0)),
            pl.BlockSpec((v, t_width), lambda i: (0, 0)),
            pl.BlockSpec((t_width, t_width), lambda i: (0, 0)),
            pl.BlockSpec((v, t_width), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((v, t_width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((v, t_width), w_tile.dtype),
        interpret=True,
    )(w_tile, wold_tile, q_tile, p_tile)


def _phase2_tile_h_kernel(ht_ref, ho_ref, s_ref, r_ref, o_ref, *, t_width, eps):
    ht = ht_ref[...]
    ho = ho_ref[...]
    s = s_ref[...]
    r = r_ref[...]
    for t in range(t_width):
        s_new = ht[:, :t] @ s[:t, t] if t > 0 else 0.0
        s_old = ho[:, t:] @ s[t:, t]
        col = jnp.maximum(eps, ht[:, t] + r[:, t] - s_new - s_old)
        ht = ht.at[:, t].set(col)
    o_ref[...] = ht


def _row_block(n, want):
    return want if n % want == 0 else n


@functools.partial(jax.jit, static_argnames=("eps", "bv"))
def phase2_tile_h(h_tile, hold_tile, s_tile, r_tile, eps=EPS, bv=1024):
    """H-flavor phase 2 (no normalization): rows are independent, so the
    grid blocks the row dimension."""
    d, t_width = h_tile.shape
    bv = min(_row_block(d, bv), d)
    grid = (d // bv,)
    return pl.pallas_call(
        functools.partial(_phase2_tile_h_kernel, t_width=t_width, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, t_width), lambda i: (i, 0)),
            pl.BlockSpec((bv, t_width), lambda i: (i, 0)),
            pl.BlockSpec((t_width, t_width), lambda i: (0, 0)),
            pl.BlockSpec((bv, t_width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bv, t_width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, t_width), h_tile.dtype),
        interpret=True,
    )(h_tile, hold_tile, s_tile, r_tile)


# ---------------------------------------------------------------------------
# Faithful Alg. 4 / Alg. 5 kernel pair (per-column, V-blocked).
# ---------------------------------------------------------------------------


def _phase2_col_kernel(wt_ref, wo_ref, qc_ref, pc_ref, col_ref, part_ref, *, t_rel, eps):
    wt = wt_ref[...]
    wo = wo_ref[...]
    qc = qc_ref[...]
    pc = pc_ref[...]
    s_new = wt[:, :t_rel] @ qc[:t_rel] if t_rel > 0 else 0.0
    s_old = wo[:, t_rel:] @ qc[t_rel:]
    col = jnp.maximum(eps, wt[:, t_rel] + pc - s_new - s_old)
    col_ref[...] = col
    # Block-level reduction (Alg. 4 lines 16-29): this program's partial.
    part_ref[...] = jnp.sum(col * col, keepdims=True)


@functools.partial(jax.jit, static_argnames=("t_rel", "eps", "bv"))
def phase2_col(w_tile, wold_tile, q_col, p_col, t_rel, eps=EPS, bv=1024):
    """Update one in-tile column (Alg. 4). Returns (new_col, partials):
    partials has one entry per grid block; Layer 2 folds them
    (jnp.sum) — the deterministic stand-in for atomicAdd."""
    v, t_width = w_tile.shape
    bv = min(_row_block(v, bv), v)
    grid = (v // bv,)
    return pl.pallas_call(
        functools.partial(_phase2_col_kernel, t_rel=t_rel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, t_width), lambda i: (i, 0)),
            pl.BlockSpec((bv, t_width), lambda i: (i, 0)),
            pl.BlockSpec((t_width,), lambda i: (0,)),
            pl.BlockSpec((bv,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bv,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((v,), w_tile.dtype),
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
        ],
        interpret=True,
    )(w_tile, wold_tile, q_col, p_col)


def _norm_scale_kernel(col_ref, inv_ref, o_ref):
    o_ref[...] = col_ref[...] * inv_ref[0]


@functools.partial(jax.jit, static_argnames=("bv",))
def norm_scale(col, inv, bv=1024):
    """Alg. 5: scale the column by the published inverse norm."""
    v = col.shape[0]
    bv = min(_row_block(v, bv), v)
    grid = (v // bv,)
    inv = jnp.reshape(inv, (1,))
    return pl.pallas_call(
        _norm_scale_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bv,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v,), col.dtype),
        interpret=True,
    )(col, inv)


def phase2_tile_w_faithful(w_tile, wold_tile, q_tile, p_tile, eps=EPS, bv=1024):
    """Whole-tile W phase 2 assembled from the per-column Alg. 4/5 kernel
    pair (host loop = Alg. 3 lines 13-19). Test/reference path."""
    t_width = w_tile.shape[1]
    wt = w_tile
    for t in range(t_width):
        col, partials = phase2_col(wt, wold_tile, q_tile[:, t], p_tile[:, t], t, eps=eps, bv=bv)
        norm2 = jnp.sum(partials)
        inv = jnp.where(norm2 > 0.0, jax.lax.rsqrt(norm2), 1.0)
        col = norm_scale(col, inv, bv=bv)
        wt = wt.at[:, t].set(col)
    return wt

"""Layer-1 Pallas kernel: accumulating panel GEMM.

``C <- C + alpha * A @ B`` — the workhorse of PL-NMF phases 1 and 3
(Alg. 2 lines 12 and 40), where ``A`` is a tall V x T column panel of the
factor and ``B`` a T x n slice of the Gram matrix.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks (V/bm,
n/bn) output tiles; each program streams the full T-deep stripe of A and B
through VMEM and hits the MXU with a single (bm x T) @ (T x bn) dot in
f32. T <= 16 and n <= K <= 240, so per-program VMEM is
bm*T + T*bn + bm*bn floats ~= 1 MiB at bm=512, bn=240 — far under the
16 MiB budget; the paper's cuBLAS panel dgemm plays the same role on GPU.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; lowering through the interpreter emits plain HLO that both
jax and the rust runtime execute identically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _panel_gemm_kernel(a_ref, b_ref, c_ref, o_ref, *, alpha):
    a = a_ref[...]
    b = b_ref[...]
    c = c_ref[...]
    o_ref[...] = c + alpha * jnp.dot(
        a, b, preferred_element_type=jnp.float32
    )


def _block(n, b):
    """Largest divisor-friendly block: use b if it divides n, else n."""
    return b if n % b == 0 else n


@functools.partial(jax.jit, static_argnames=("alpha", "bm", "bn"))
def panel_gemm(a, b, c, alpha=-1.0, bm=512, bn=256):
    """C + alpha * A @ B via a blocked Pallas kernel.

    a: (m, t) factor panel; b: (t, n) Gram slice; c: (m, n) accumulator.
    """
    m, t = a.shape
    t2, n = b.shape
    assert t == t2, f"inner dims {t} vs {t2}"
    assert c.shape == (m, n), f"c shape {c.shape} != {(m, n)}"
    if m == 0 or n == 0 or t == 0:
        return c
    bm = min(_block(m, bm), m)
    bn = min(_block(n, bn), n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_panel_gemm_kernel, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, t), lambda i, j: (i, 0)),
            pl.BlockSpec((t, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(a, b, c)


def panel_gemm_ref(a, b, c, alpha=-1.0):
    """jnp reference."""
    return c + alpha * a @ b

"""Layer-2 correctness: the tiled model graphs vs the FAST-HALS oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def problem(v, d, k, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0, 1, (v, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 1, (v, k)).astype(np.float32))
    w = w / jnp.linalg.norm(w, axis=0, keepdims=True)
    h = jnp.asarray(rng.uniform(0, 1, (d, k)).astype(np.float32))
    return a, w, h


@pytest.mark.parametrize("tile", [1, 2, 3, 4, 8])
def test_step_dense_matches_oracle_all_tiles(tile):
    a, w, h = problem(37, 23, 8, 5)
    w1, h1 = model.plnmf_step_dense(a, w, h, tile=tile)
    w2, h2 = ref.fast_hals_step(a, w, h)
    np.testing.assert_allclose(w1, w2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-3, atol=2e-3)


def test_update_w_matches_oracle():
    a, w, h = problem(40, 25, 6, 9)
    q = h.T @ h
    p = a @ h
    got = model.plnmf_update_w(w, q, p, tile=3)
    want = ref.hals_update_w(w, q, p)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_update_h_matches_oracle():
    a, w, h = problem(40, 25, 6, 10)
    s = w.T @ w
    r = a.T @ w
    got = model.plnmf_update_h(h, s, r, tile=4)
    want = ref.hals_update_h(h, s, r)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_half_steps_compose_to_full_step():
    """The sparse-path pair (update_h_from_r, update_w_from_p) must equal
    the fused dense step when fed the same products."""
    a, w, h = problem(30, 20, 6, 11)
    r = a.T @ w
    h1 = model.plnmf_update_h_from_r(w, h, r, tile=3)
    p = a @ h1
    w1 = model.plnmf_update_w_from_p(w, h1, p, tile=3)
    w2, h2 = model.plnmf_step_dense(a, w, h, tile=3)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-5)


def test_mu_step_matches_ref():
    a, w, h = problem(25, 15, 4, 12)
    w1, h1 = model.mu_step_dense(a, w, h)
    w2, h2 = ref.mu_step(a, w, h)
    np.testing.assert_allclose(w1, w2, rtol=1e-5)
    np.testing.assert_allclose(h1, h2, rtol=1e-5)


def test_rel_error_gram_trick_matches_direct():
    a, w, h = problem(30, 22, 5, 13)
    fast = float(model.rel_error_dense(a, w, h))
    slow = float(ref.rel_error(a, w, h))
    assert abs(fast - slow) < 1e-4


def test_convergence_over_iterations():
    a, w, h = problem(50, 35, 6, 14)
    errs = [float(model.rel_error_dense(a, w, h))]
    for _ in range(8):
        w, h = model.plnmf_step_dense(a, w, h, tile=3)
        errs.append(float(model.rel_error_dense(a, w, h)))
    assert errs[-1] < errs[0] * 0.9
    # HALS is monotone non-increasing (fp slack)
    assert all(b <= a + 1e-4 for a, b in zip(errs, errs[1:]))


@settings(max_examples=12, deadline=None)
@given(
    v=st.integers(5, 50),
    d=st.integers(5, 40),
    k=st.integers(2, 10),
    data=st.data(),
)
def test_step_hypothesis_tile_invariance(v, d, k, data):
    """All tile widths produce the same update (fp tolerance), i.e. the
    associativity reorder does not change the math."""
    tile_a = data.draw(st.integers(1, k))
    tile_b = data.draw(st.integers(1, k))
    a, w, h = problem(v, d, k, v * 100 + d * 10 + k)
    wa, ha = model.plnmf_step_dense(a, w, h, tile=tile_a)
    wb, hb = model.plnmf_step_dense(a, w, h, tile=tile_b)
    np.testing.assert_allclose(wa, wb, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(ha, hb, rtol=2e-2, atol=2e-3)


def test_nonnegativity_and_unit_norm_invariants():
    a, w, h = problem(45, 30, 7, 15)
    for _ in range(3):
        w, h = model.plnmf_step_dense(a, w, h, tile=3)
    w_np, h_np = np.array(w), np.array(h)
    assert (w_np > 0).all()
    assert (h_np > 0).all()
    np.testing.assert_allclose((w_np * w_np).sum(axis=0), 1.0, rtol=1e-3)

"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for the kernels that end up inside
every AOT artifact.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import panel_gemm as pg
from compile.kernels import phase2 as p2
from compile.kernels import ref


def rand(rng, *shape):
    return jnp.asarray(rng.uniform(0.0, 1.0, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# panel_gemm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,t,n,alpha",
    [(8, 2, 4, -1.0), (64, 16, 32, -1.0), (33, 5, 7, 1.0), (1, 1, 1, -2.5), (100, 3, 240, -1.0)],
)
def test_panel_gemm_matches_ref(m, t, n, alpha):
    rng = np.random.default_rng(m * 1000 + n)
    a, b, c = rand(rng, m, t), rand(rng, t, n), rand(rng, m, n)
    got = pg.panel_gemm(a, b, c, alpha=alpha)
    want = pg.panel_gemm_ref(a, b, c, alpha=alpha)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 90),
    t=st.integers(1, 17),
    n=st.integers(1, 50),
    bm=st.sampled_from([8, 32, 512]),
    bn=st.sampled_from([8, 64, 256]),
)
def test_panel_gemm_hypothesis_shapes(m, t, n, bm, bn):
    rng = np.random.default_rng(m * 7919 + t * 31 + n)
    a, b, c = rand(rng, m, t), rand(rng, t, n), rand(rng, m, n)
    got = pg.panel_gemm(a, b, c, alpha=-1.0, bm=bm, bn=bn)
    want = pg.panel_gemm_ref(a, b, c, alpha=-1.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_panel_gemm_empty_inner_dim_is_identity():
    rng = np.random.default_rng(3)
    c = rand(rng, 5, 4)
    a = jnp.zeros((5, 0), jnp.float32)
    b = jnp.zeros((0, 4), jnp.float32)
    np.testing.assert_array_equal(pg.panel_gemm(a, b, c), c)


# ---------------------------------------------------------------------------
# phase 2 kernels vs a direct oracle of the in-tile update
# ---------------------------------------------------------------------------


def phase2_w_oracle(wt, wo, q, p, eps=1e-16):
    """In-tile W phase 2 with interleaved norm, numpy loop."""
    wt = np.array(wt, dtype=np.float64)
    wo = np.array(wo, dtype=np.float64)
    q = np.array(q, dtype=np.float64)
    p = np.array(p, dtype=np.float64)
    T = wt.shape[1]
    for t in range(T):
        s = wt[:, :t] @ q[:t, t] + wo[:, t:] @ q[t:, t]
        col = np.maximum(eps, wt[:, t] + p[:, t] - s)
        col = col / max(np.sqrt(np.sum(col * col)), 1e-300)
        wt[:, t] = col
    return wt


def phase2_h_oracle(ht, ho, s_, r, eps=1e-16):
    ht = np.array(ht, dtype=np.float64)
    ho = np.array(ho, dtype=np.float64)
    s_ = np.array(s_, dtype=np.float64)
    r = np.array(r, dtype=np.float64)
    T = ht.shape[1]
    for t in range(T):
        s = ht[:, :t] @ s_[:t, t] + ho[:, t:] @ s_[t:, t]
        ht[:, t] = np.maximum(eps, ht[:, t] + r[:, t] - s)
    return ht


def make_tile_problem(v, T, seed):
    rng = np.random.default_rng(seed)
    f = rand(rng, v + 3, T)
    q = f.T @ f  # SPD-ish tile of a Gram
    wt = rand(rng, v, T)
    wo = rand(rng, v, T)
    p = rand(rng, v, T)
    return wt, wo, q, p


@pytest.mark.parametrize("v,T", [(16, 1), (40, 3), (64, 8), (37, 5), (1024, 4), (1030, 4)])
def test_phase2_tile_w_matches_oracle(v, T):
    wt, wo, q, p = make_tile_problem(v, T, v * 10 + T)
    got = p2.phase2_tile_w(wt, wo, q, p)
    want = phase2_w_oracle(wt, wo, q, p)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("d,T,bv", [(16, 2, 1024), (2048, 4, 1024), (100, 7, 16)])
def test_phase2_tile_h_matches_oracle(d, T, bv):
    ht, ho, s_, r = make_tile_problem(d, T, d + T)
    got = p2.phase2_tile_h(ht, ho, s_, r, bv=bv)
    want = phase2_h_oracle(ht, ho, s_, r)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_faithful_alg45_pair_matches_tile_kernel():
    """The per-column Alg. 4/5 realization == the whole-tile kernel."""
    wt, wo, q, p = make_tile_problem(96, 6, 42)
    a = p2.phase2_tile_w(wt, wo, q, p)
    b = p2.phase2_tile_w_faithful(wt, wo, q, p, bv=32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(v=st.integers(2, 80), T=st.integers(1, 9), seed=st.integers(0, 10_000))
def test_phase2_w_hypothesis(v, T, seed):
    wt, wo, q, p = make_tile_problem(v, T, seed)
    got = np.array(p2.phase2_tile_w(wt, wo, q, p))
    want = phase2_w_oracle(wt, wo, q, p)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
    # invariants: positive and unit-norm columns
    assert (got > 0).all()
    np.testing.assert_allclose((got * got).sum(axis=0), 1.0, rtol=1e-3)


def test_norm_scale_kernel():
    rng = np.random.default_rng(1)
    col = rand(rng, 48)
    out = p2.norm_scale(col, jnp.float32(0.5), bv=16)
    np.testing.assert_allclose(out, col * 0.5, rtol=1e-6)


def test_phase2_col_partials_sum_to_norm():
    wt, wo, q, p = make_tile_problem(64, 4, 7)
    col, partials = p2.phase2_col(wt, wo, q[:, 2], p[:, 2], 2, bv=16)
    assert partials.shape == (4,)
    np.testing.assert_allclose(jnp.sum(partials), jnp.sum(col * col), rtol=1e-5)


# ---------------------------------------------------------------------------
# oracle self-checks
# ---------------------------------------------------------------------------


def test_ref_step_decreases_error():
    rng = np.random.default_rng(11)
    a = rand(rng, 30, 20)
    w = rand(rng, 30, 5)
    w = w / jnp.linalg.norm(w, axis=0, keepdims=True)
    h = rand(rng, 20, 5)
    e0 = float(ref.rel_error(a, w, h))
    for _ in range(5):
        w, h = ref.fast_hals_step(a, w, h)
    e1 = float(ref.rel_error(a, w, h))
    assert e1 < e0
    # unit-norm W invariant
    np.testing.assert_allclose(np.sum(np.array(w) ** 2, axis=0), 1.0, rtol=1e-4)


def test_ref_mu_decreases_error():
    rng = np.random.default_rng(13)
    a = rand(rng, 25, 18)
    w, h = rand(rng, 25, 4), rand(rng, 18, 4)
    e0 = float(ref.rel_error(a, w, h))
    for _ in range(10):
        w, h = ref.mu_step(a, w, h)
    assert float(ref.rel_error(a, w, h)) < e0

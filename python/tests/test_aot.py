"""AOT pipeline integrity: lowering, manifest, and an HLO round-trip
executed through xla_client — the same load path the rust runtime takes.
"""

import json
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def test_select_tile_matches_rust_model():
    # Mirrors rust cost_model tests: model T* = 8.94 / 12.64 / 15.49.
    assert aot.select_tile(80) == 9
    assert aot.select_tile(160) == 13
    assert aot.select_tile(240) == 15
    assert aot.select_tile(1) == 1
    assert aot.select_tile(4) == 2


def test_build_writes_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.build(d, [("tiny", 8)], verbose=False)
        names = {a["name"] for a in manifest["artifacts"]}
        t = aot.select_tile(8)
        assert f"plnmf_step__tiny_k8_t{t}" in names
        assert f"mu_step__tiny_k8_t{t}" in names
        for a in manifest["artifacts"]:
            path = os.path.join(d, a["file"])
            assert os.path.exists(path)
            head = open(path).read(200)
            assert "HloModule" in head
        # manifest on disk parses and matches
        with open(os.path.join(d, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk["artifacts"] == sorted(
            manifest["artifacts"], key=lambda a: a["name"]
        ) or len(on_disk["artifacts"]) == len(manifest["artifacts"])


def test_build_is_incremental():
    with tempfile.TemporaryDirectory() as d:
        aot.build(d, [("tiny", 8)], verbose=False)
        mtimes = {
            f: os.path.getmtime(os.path.join(d, f))
            for f in os.listdir(d)
            if f.endswith(".hlo.txt")
        }
        aot.build(d, [("tiny", 8)], verbose=False)  # second run: all cached
        for f, m in mtimes.items():
            assert os.path.getmtime(os.path.join(d, f)) == m, f"{f} re-lowered"


def test_sparse_profile_gets_half_step_artifacts():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.build(d, [("tiny-sparse", 8)], verbose=False)
        fns = {a["fn"] for a in manifest["artifacts"]}
        assert fns == {"plnmf_update_h", "plnmf_update_w", "mu_update_h", "mu_update_w"}
        for a in manifest["artifacts"]:
            assert a["sparse"] is True
            assert a["inputs"][0]["shape"] == [80, 8]  # W


def test_hlo_text_parses_back_with_expected_signature():
    """The interchange contract: the emitted HLO text must parse back
    through XLA's text parser (the same parser the rust runtime's
    `HloModuleProto::from_text_file` uses) with the expected entry
    signature. Full execute-and-compare coverage lives in the rust
    integration test (rust/tests/integration_runtime.rs), which drives
    the actual consumer code path."""
    v, d, k, tile = 20, 12, 4, 2
    lowered = jax.jit(model.plnmf_step_dense, static_argnames=("tile",)).lower(
        jax.ShapeDtypeStruct((v, d), jnp.float32),
        jax.ShapeDtypeStruct((v, k), jnp.float32),
        jax.ShapeDtypeStruct((d, k), jnp.float32),
        tile=tile,
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    mod = xc._xla.hlo_module_from_text(text)
    sig = xc._xla.HloPrintOptions.short_parsable()
    reparsed = mod.to_string(sig)
    assert "f32[20,12]" in reparsed  # A
    assert "f32[20,4]" in reparsed  # W
    assert "f32[12,4]" in reparsed  # H
    # return_tuple=True => tuple root with both outputs
    assert "(f32[20,4]" in reparsed.replace(" ", "") or "tuple" in reparsed


def test_manifest_shapes_consistent_with_profiles():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.build(d, [("tiny", 8), ("tiny-sparse", 8)], verbose=False)
        for a in manifest["artifacts"]:
            v, dd, sparse = aot.PROFILES[a["dataset"]]
            assert a["v"] == v and a["d"] == dd and a["sparse"] == sparse
            for spec in a["inputs"] + a["outputs"]:
                assert spec["dtype"] == "f32"
                assert all(s > 0 for s in spec["shape"])

"""Test bootstrap: import path + an offline fallback for `hypothesis`.

* Puts `python/` on sys.path so `from compile import ...` works whether
  pytest runs from the repo root (`pytest python/tests`) or from
  `python/` (`pytest tests`).
* If the real `hypothesis` package is unavailable (offline container),
  installs a minimal deterministic shim exposing the subset these tests
  use (`given`, `settings`, `strategies.integers/sampled_from`). The
  shim runs each property for `max_examples` seeded-random samples, so
  the property tests keep their coverage — just without shrinking.
"""

import functools
import inspect
import os
import random
import sys
import types
import zlib

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    class _Data:
        """Interactive draws (`st.data()`), sharing the trial's rng."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.sample(self._rng)

    def data():
        return _Strategy(_Data)

    def given(**strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper._max_examples = 20
            # Hide the property's parameters from pytest's fixture
            # resolution (they are drawn, not injected).
            wrapper.__signature__ = inspect.Signature()
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            return wrapper

        return decorate

    def settings(max_examples=20, deadline=None, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.sampled_from = sampled_from
    st_mod.data = data
    hyp.strategies = st_mod
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
